"""Serving robustness (ISSUE 7 / DESIGN.md §14): deadlines, admission
control, retry/backoff, lifecycle.

Contracts pinned here:
  * every submitted request resolves EXACTLY once — shed, expired,
    drained or served — so ``submit(...).get()`` never blocks forever;
  * ``close(drain=True)`` answers every queued request, ``drain=False``
    resolves the backlog with typed shutdown errors, and ``submit``
    after close raises ``ServerClosed``;
  * a failed background compaction leaves the old snapshot serving
    bitwise untouched, surfaces in stats/summary, and resets capacity
    hints; a transient failure retries with backoff and succeeds;
  * deadlines are absolute and checked at admission, window formation,
    before the fit and between device rounds — typed, never silent;
  * the policy pieces (RetryPolicy, TokenBucket, AdmissionQueue) behave
    deterministically in isolation.

Every blocking ``get`` in this file carries a timeout: a hang here is a
deadlock bug, and the bounded waits convert it into a visible failure
instead of a wedged suite.
"""
import queue
import threading
import time

import numpy as np
import pytest

from repro.core.engine import SearchEngine
from repro.core.errors import (DeadlineExceeded, TransientDeviceError,
                               deadline_after)
from repro.serve.engine import IngestRequest, QueryRequest, QueryServer
from repro.serve.faults import FaultInjector, FaultSpec
from repro.serve.policy import (AdmissionQueue, Overloaded, RateLimited,
                                RetryPolicy, ServerClosed, TokenBucket)

ENG = dict(n_subsets=4, subset_dim=4, block=64)
GET_S = 120            # generous bound: first query pays jit compile


def _data(n=500, d=16, seed=0):
    return np.random.default_rng(seed).normal(
        0, 1, (n, d)).astype(np.float32)


def _labels():
    return list(range(10)), list(range(100, 150))


@pytest.fixture(scope="module")
def base_x():
    return _data()


# ----------------------------------------------------------------------
# policy units
# ----------------------------------------------------------------------

def test_retry_policy_retries_transient_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientDeviceError("flake")
        return "ok"

    naps = []
    pol = RetryPolicy(max_attempts=5, backoff_s=0.01, seed=7)
    assert pol.call(flaky, sleep=naps.append) == "ok"
    assert calls["n"] == 3 and len(naps) == 2
    assert naps[1] > naps[0] > 0          # exponential, jittered


def test_retry_policy_gives_up_and_classifies():
    pol = RetryPolicy(max_attempts=2, backoff_s=0.0)
    calls = {"n": 0}

    def always():
        calls["n"] += 1
        raise TransientDeviceError("flake")
    with pytest.raises(TransientDeviceError):
        pol.call(always, sleep=lambda s: None)
    assert calls["n"] == 2
    # non-retryable types fail on the FIRST attempt
    calls["n"] = 0

    def bad():
        calls["n"] += 1
        raise ValueError("bug")
    with pytest.raises(ValueError):
        pol.call(bad, sleep=lambda s: None)
    assert calls["n"] == 1
    # DeadlineExceeded is never retryable, whatever ``retryable`` says
    assert not pol.classify(DeadlineExceeded("late"))


def test_retry_policy_respects_deadline_budget():
    pol = RetryPolicy(max_attempts=10, backoff_s=10.0)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        raise TransientDeviceError("flake")
    # remaining budget (~50 ms) < backoff (10 s): no retry, fail fast
    with pytest.raises(TransientDeviceError):
        pol.call(flaky, deadline_s=deadline_after(0.05),
                 sleep=lambda s: None)
    assert calls["n"] == 1


def test_retry_backoff_is_seeded_deterministic():
    a = RetryPolicy(max_attempts=4, backoff_s=0.01, seed=3)
    b = RetryPolicy(max_attempts=4, backoff_s=0.01, seed=3)
    assert [a.delay_s(i) for i in (1, 2, 3)] == \
        [b.delay_s(i) for i in (1, 2, 3)]


def test_token_bucket_burst_and_refill():
    t = {"now": 0.0}
    tb = TokenBucket(rate=10.0, burst=2.0, clock=lambda: t["now"])
    assert tb.try_acquire() and tb.try_acquire()
    assert not tb.try_acquire()           # burst exhausted
    t["now"] += 0.1                       # 1 token refilled
    assert tb.try_acquire()
    assert not tb.try_acquire()
    t["now"] += 10.0                      # refill caps at burst
    assert tb.tokens == pytest.approx(2.0)


def test_admission_queue_reject_newest():
    q = AdmissionQueue(depth=2)
    assert q.offer("a")[0] and q.offer("b")[0]
    admitted, evicted = q.offer("c")
    assert not admitted and evicted is None
    assert len(q) == 2 and q.depth_peak == 2
    assert q.pop(0.01) == "a"             # FIFO preserved


def test_admission_queue_reject_largest_fit():
    q = AdmissionQueue(depth=2, shed_policy="reject-largest-fit")
    q.offer("small", cost=5)
    q.offer("big", cost=50)
    admitted, evicted = q.offer("tiny", cost=1)
    assert admitted and evicted == "big"  # largest fit shed
    # a newcomer at least as costly as every queued entry is refused
    admitted, evicted = q.offer("huge", cost=100)
    assert not admitted and evicted is None
    assert q.drain() == ["small", "tiny"]
    assert len(q) == 0


def test_fault_injector_deterministic_schedule():
    specs = (FaultSpec("append", at_calls=(2,)),
             FaultSpec("compact", prob=0.5, action="slow", delay_s=0.0))

    def schedule(seed):
        inj = FaultInjector(seed=seed, specs=specs)
        fired = []
        for _ in range(20):
            try:
                inj.check("append")
            except TransientDeviceError:
                fired.append(("append", inj.calls("append")))
            inj.check("compact")
        return fired + [(r.site, r.call) for r in inj.fired]

    assert schedule(11) == schedule(11)           # replayable
    assert ("append", 2) in schedule(11)          # at_calls honoured
    assert schedule(11) != schedule(12)           # seed matters


# ----------------------------------------------------------------------
# deadlines
# ----------------------------------------------------------------------

def test_engine_query_deadline_expired_before_fit(base_x):
    eng = SearchEngine(base_x, **ENG)
    pos, neg = _labels()
    with pytest.raises(DeadlineExceeded):
        eng.query(pos, neg, model="dbranch",
                  deadline_s=time.monotonic() - 0.01)


def test_submit_rejects_expired_deadline(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)                # not started: admission only
    pos, neg = _labels()
    resp = srv.submit(QueryRequest(0, pos, neg,
                                   deadline_s=time.monotonic() - 1)
                      ).get(timeout=5)
    assert not resp.ok and resp.error_type == "deadline_exceeded"
    assert srv.stats["rejected_deadline"] == 1


def test_deadline_expires_while_queued(base_x):
    """Window-formation checkpoint: budget burned in the queue yields a
    typed response, and the server keeps serving live requests."""
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)
    pos, neg = _labels()
    dead = srv.submit(QueryRequest(0, pos, neg,
                                   deadline_s=deadline_after(0.03)))
    live = srv.submit(QueryRequest(1, pos, neg))
    time.sleep(0.1)                       # burn request 0's budget queued
    srv.start()
    r0 = dead.get(timeout=GET_S)
    r1 = live.get(timeout=GET_S)
    srv.close()
    assert not r0.ok and r0.error_type == "deadline_exceeded"
    assert "queued" in r0.error
    assert r1.ok
    assert srv.stats["expired_in_queue"] == 1


def test_default_deadline_stamped_at_admission(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, default_deadline_s=30.0)
    pos, neg = _labels()
    req = QueryRequest(0, pos, neg)
    srv.submit(req)
    assert req.deadline_s is not None
    assert req.deadline_s - time.monotonic() == pytest.approx(30.0, abs=1.0)
    srv.close(drain=False)


# ----------------------------------------------------------------------
# admission control / backpressure
# ----------------------------------------------------------------------

def test_queue_full_typed_rejection(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, queue_depth=2)  # not started: queue fills
    pos, neg = _labels()
    outs = [srv.submit(QueryRequest(i, pos, neg)) for i in range(4)]
    r2 = outs[2].get(timeout=5)
    r3 = outs[3].get(timeout=5)
    assert not r2.ok and r2.error_type == "overloaded"
    assert not r3.ok and r3.error_type == "overloaded"
    assert srv.stats["rejected_overloaded"] == 2
    assert srv.stats["admitted"] == 2
    srv.close(drain=False)                # resolves the 2 queued
    for o in outs[:2]:
        assert o.get(timeout=5).error_type == "shutdown"


def test_shed_policy_evicts_largest_fit(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, queue_depth=2,
                      shed_policy="reject-largest-fit")
    big = QueryRequest(0, list(range(40)), list(range(100, 200)))
    small = QueryRequest(1, [0, 1], [100, 101])
    tiny = QueryRequest(2, [0], [100])
    out_big = srv.submit(big)
    srv.submit(small)
    out_tiny = srv.submit(tiny)
    # the expensive fit was shed to admit the cheap newcomer
    r = out_big.get(timeout=5)
    assert not r.ok and r.error_type == "overloaded"
    assert "largest-fit" in r.error
    assert srv.stats["evicted"] == 1
    assert out_tiny.empty()               # tiny is queued, not rejected
    srv.close(drain=False)


def test_rate_limit_per_source(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, rate_limit=(0.001, 2))   # ~no refill in-test
    pos, neg = _labels()
    rs = [srv.submit(QueryRequest(i, pos, neg, source="alice")).empty()
          for i in range(3)]
    assert rs == [True, True, False]      # third resolved = rejected
    # a different source has its own bucket
    assert srv.submit(QueryRequest(9, pos, neg, source="bob")).empty()
    assert srv.stats["rejected_rate_limited"] == 1
    srv.close(drain=False)


def test_degraded_mode_clamps_max_results(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, max_results=50, queue_depth=4,
                      degraded_max_results=5, soft_depth_frac=0.5)
    req = QueryRequest(0, *_labels())
    assert srv._query_kwargs(req)["max_results"] == 50
    srv._degraded = True                  # what _update_health sets
    assert srv._query_kwargs(req)["max_results"] == 5
    # a request's own kwargs clamp too (never widened)
    req2 = QueryRequest(1, *_labels(), kwargs={"max_results": 3})
    assert srv._query_kwargs(req2)["max_results"] == 3


def test_degraded_windows_under_backlog(base_x):
    """End-to-end: a backlog above the soft watermark serves clamped
    windows and reports a degraded health state while it lasts."""
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, max_results=50, queue_depth=8,
                      degraded_max_results=4, soft_depth_frac=0.25,
                      max_batch=2)
    pos, neg = _labels()
    outs = [srv.submit(QueryRequest(i, pos, neg)) for i in range(6)]
    assert srv.health == "ok"             # degraded is a WINDOW property
    srv.start()
    resps = [o.get(timeout=GET_S) for o in outs]
    srv.close()
    assert all(r.ok for r in resps)
    assert srv.stats["degraded_windows"] >= 1
    # at least the first window (formed with 5 queued behind it) clamped
    assert min(len(r.result.ids) for r in resps) <= 4


# ----------------------------------------------------------------------
# lifecycle
# ----------------------------------------------------------------------

def test_close_resolves_queued_requests_with_typed_errors(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)                # never started
    pos, neg = _labels()
    outs = [srv.submit(QueryRequest(i, pos, neg)) for i in range(3)]
    outs.append(srv.submit(IngestRequest(3, "append",
                                         features=_data(4))))
    srv.close(drain=False)
    for o in outs:
        r = o.get(timeout=5)              # never blocks forever
        assert not r.ok and r.error_type == "shutdown"
    assert srv.stats["shutdown_unserved"] == 4
    assert srv.summary()["health"] == "draining"


def test_submit_after_close_raises(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)
    srv.start()
    srv.close()
    with pytest.raises(ServerClosed):
        srv.submit(QueryRequest(0, *_labels()))


def test_close_drain_answers_everything(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, max_batch=2)
    pos, neg = _labels()
    outs = [srv.submit(QueryRequest(i, pos, neg)) for i in range(5)]
    srv.start()                           # backlog present at start
    srv.close(drain=True)                 # returns once all answered
    resps = [o.get(timeout=GET_S) for o in outs]
    assert all(r.ok for r in resps)
    assert srv.stats["served"] == 5
    assert srv.stats["shutdown_unserved"] == 0


def test_close_is_idempotent(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)
    srv.start()
    srv.close()
    srv.close()                           # second close is a no-op
    srv.close(drain=False)


# ----------------------------------------------------------------------
# compaction robustness
# ----------------------------------------------------------------------

def _live_server(x, faults=None, **kw):
    eng = SearchEngine(x, **ENG, live=True, faults=faults)
    return eng, QueryServer(eng, **kw)


def test_compaction_failure_keeps_old_snapshot(base_x):
    inj = FaultInjector(specs=[FaultSpec("compact", at_calls=(1, 2, 3))])
    eng, srv = _live_server(
        base_x, faults=inj,
        compaction_retry=RetryPolicy(max_attempts=3, backoff_s=0.001))
    eng.append(_data(40, seed=5))         # >1 segment: compactable
    pos, neg = _labels()
    before = eng.query(pos, neg, model="dbranch", max_results=20)
    epoch0 = eng._catalog.epoch
    assert len(eng._cap_hints) > 0        # hints learned pre-failure
    rc = srv.handle_ingest(IngestRequest(0, "compact"))
    assert rc.ok and rc.info["background"]
    srv._compact_thread.join(timeout=30)
    assert not srv._compact_thread.is_alive()
    # every attempt failed BEFORE the merge: snapshot + epoch untouched
    assert eng._catalog.epoch == epoch0
    assert srv.stats["compaction_errors"] == 1
    assert srv.stats["compaction_retries"] == 2
    assert "injected fault" in srv.summary()["last_compaction_error"]
    assert srv.summary()["health"] == "degraded"
    # conservative reset: hints observed around the failure are void
    assert len(eng._cap_hints) == 0
    # serving continues, bitwise on the old snapshot
    after = eng.query(pos, neg, model="dbranch", max_results=20)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_array_equal(before.scores, after.scores)
    srv.close()


def test_compaction_transient_failure_retries_to_success(base_x):
    inj = FaultInjector(specs=[FaultSpec("compact", at_calls=(1,))])
    eng, srv = _live_server(
        base_x, faults=inj,
        compaction_retry=RetryPolicy(max_attempts=3, backoff_s=0.001))
    eng.append(_data(40, seed=5))
    epoch0 = eng._catalog.epoch
    rc = srv.handle_ingest(IngestRequest(0, "compact"))
    assert rc.ok
    srv._compact_thread.join(timeout=30)
    assert srv.stats["compaction_retries"] == 1
    assert srv.stats["compaction_errors"] == 0
    assert eng._catalog.epoch == epoch0 + 1       # swap happened
    assert len(eng._catalog.snapshot().segments) == 1
    srv.close()


def test_concurrent_compact_requests_coalesce(base_x):
    inj = FaultInjector(specs=[FaultSpec("compact", action="slow",
                                         at_calls=(1,), delay_s=0.3)])
    eng, srv = _live_server(base_x, faults=inj)
    eng.append(_data(40, seed=5))
    r1 = srv.handle_ingest(IngestRequest(0, "compact"))
    r2 = srv.handle_ingest(IngestRequest(1, "compact"))
    assert r1.ok and r2.ok
    assert r2.info.get("coalesced")       # no second worker thread
    srv._compact_thread.join(timeout=30)
    assert srv.stats["compactions"] == 2
    assert inj.calls("compact") == 1      # ONE merge ran
    srv.close()


# ----------------------------------------------------------------------
# query-path retries + batch fallback billing
# ----------------------------------------------------------------------

def test_query_retries_transient_device_fault(base_x):
    inj = FaultInjector(specs=[FaultSpec("device_sync", at_calls=(1,))])
    eng = SearchEngine(base_x, **ENG, faults=inj)
    srv = QueryServer(eng, retry_policy=RetryPolicy(max_attempts=3,
                                                    backoff_s=0.001))
    resp = srv.handle(QueryRequest(0, *_labels()))
    assert resp.ok
    assert srv.stats["retries"] == 1
    # the retry re-ran the whole query: parity with a clean engine
    clean = SearchEngine(base_x, **ENG)
    want = clean.query(*_labels(), model="dbranch")
    np.testing.assert_array_equal(resp.result.ids, want.ids)


def test_batch_fallback_bills_wasted_wall(base_x):
    inj = FaultInjector(specs=[FaultSpec("fused_query", at_calls=(1,))])
    eng = SearchEngine(base_x, **ENG, faults=inj)
    srv = QueryServer(eng)                # no retry: fall back sequential
    pos, neg = _labels()
    reqs = [QueryRequest(i, pos, neg) for i in range(3)]
    sum0 = srv.stats["latency_sum"]
    resps = srv.handle_batch(reqs)
    assert all(r.ok for r in resps)
    assert srv.stats["batch_fallbacks"] == 1
    assert srv.stats["batches"] == 0      # the window never ran batched
    assert srv.stats["served"] == 3
    # the failed attempt's wall is billed to every request in the window
    assert srv.stats["latency_sum"] - sum0 == pytest.approx(
        sum(r.latency_s for r in resps), rel=1e-6)


def test_batch_deadline_exceeded_short_circuits(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)
    pos, neg = _labels()
    dl = time.monotonic() - 0.01          # already expired
    reqs = [QueryRequest(i, pos, neg, deadline_s=dl) for i in range(2)]
    resps = srv.handle_batch(reqs)
    assert all(not r.ok for r in resps)
    assert all(r.error_type == "deadline_exceeded" for r in resps)
    assert srv.stats["batch_fallbacks"] == 0      # no pointless retry
    assert srv.stats["errors"] == 2


# ----------------------------------------------------------------------
# serving-thread stat races + expired-backlog recursion (ISSUE 9)
# ----------------------------------------------------------------------

class _InstantResult:
    """Microsecond stand-in for QueryResult: the hammer and backlog
    tests exercise the SERVER's bookkeeping, not the device path."""

    def __init__(self):
        self.ids = np.arange(4, dtype=np.int32)
        self.scores = np.ones(4, dtype=np.float32)
        self.train_time_s = 0.0
        self.query_time_s = 0.0
        self.stats = {"host_bytes_transferred": 32}


class _InstantEngine:
    """Duck-typed engine answering immediately on the serving thread."""
    live = True

    def __init__(self):
        self._next = 1000
        self._lock = threading.Lock()

    def query(self, pos, neg, model="dbranch", deadline_s=None, **kw):
        return _InstantResult()

    def query_batch(self, batch, deadline_s=None):
        return [_InstantResult() for _ in batch]

    def append(self, feats):
        with self._lock:
            lo = self._next
            self._next += len(feats)
        return np.arange(lo, lo + len(feats))


def _ledger_holds(stats):
    """DESIGN.md §14: every admitted request lands in exactly one
    terminal bucket. EXACT equality — a race that loses one locked
    increment breaks this."""
    return stats["admitted"] == (stats["served"] + stats["ingests"]
                                 + stats["expired_in_queue"]
                                 + stats["evicted"]
                                 + stats["shutdown_unserved"])


def test_stats_ledger_exact_under_hammer():
    """Many submit threads race the serving thread (and each other)
    across every admission outcome — admitted, overloaded, evicted,
    rate-limit-free expiry, ingests — for 100 server lifetimes. With
    any unlocked ``stats[k] += v`` on these paths the exact ledger
    equality fails within a few iterations."""
    n_threads, per_thread = 6, 20
    for it in range(100):
        srv = QueryServer(_InstantEngine(), max_batch=4,
                          batch_window_s=0.0005, queue_depth=24,
                          shed_policy="reject-largest-fit")
        srv.start()
        outs, outs_lock = [], threading.Lock()

        def worker(tid, srv=srv, outs=outs, outs_lock=outs_lock):
            rng = np.random.default_rng(tid)
            local = []
            for j in range(per_thread):
                rid = tid * 1000 + j
                draw = rng.random()
                if draw < 0.2:
                    req = IngestRequest(
                        rid, "append",
                        features=np.zeros((2, 4), np.float32))
                elif draw < 0.4:   # expires at admission or in queue
                    req = QueryRequest(rid, [0], [1],
                                       deadline_s=deadline_after(0.001))
                else:              # varied cost: exercises eviction
                    n = int(rng.integers(1, 30))
                    req = QueryRequest(rid, list(range(n)), [100])
                local.append(srv.submit(req))
            with outs_lock:
                outs.extend(local)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        srv.close(drain=bool(it % 2))     # alternate both close modes
        resps = [o.get(timeout=10) for o in outs]
        assert len(resps) == n_threads * per_thread   # all resolved
        s = srv.summary()
        assert _ledger_holds(s), f"iteration {it}: ledger drifted: {s}"
        # and every submit landed in exactly one admission bucket
        assert len(resps) == (s["admitted"] + s["rejected_overloaded"]
                              + s["rejected_rate_limited"]
                              + s["rejected_deadline"]
                              + s["submit_faults"])


def test_expired_backlog_resolves_iteratively():
    """5,000 already-expired requests queued ahead of a live one: the
    serving thread must drain them ALL with typed responses in constant
    stack. The old recursive ``_pop_live`` blew the interpreter's
    ~1000-frame recursion limit here, killing the serving thread and
    stranding every later request."""
    srv = QueryServer(_InstantEngine())
    dl = deadline_after(2.0)
    outs = [srv.submit(QueryRequest(i, [0], [1], deadline_s=dl))
            for i in range(5000)]
    while time.monotonic() <= dl:
        time.sleep(0.01)                  # the whole backlog is now dead
    srv.start()
    live = srv.submit(QueryRequest(9999, [0], [1]))
    resps = [o.get(timeout=GET_S) for o in outs]
    assert all(r.error_type == "deadline_exceeded" for r in resps)
    assert srv.stats["expired_in_queue"] == 5000
    # the serving thread survived the drain and still serves
    assert srv._thread.is_alive()
    assert live.get(timeout=GET_S).ok
    assert _ledger_holds(srv.summary())
    srv.close()


def test_close_drain_releases_parked_hang(base_x):
    """close(drain=True) with a request parked on an injected hang:
    once the queue is empty the drain path releases the injector, so
    the parked request resolves with its REAL answer and close returns
    in query-time, not hang-time (60 s) or join-timeout (30 s)."""
    SearchEngine(base_x, **ENG).query(*_labels(), model="dbranch")
    inj = FaultInjector(specs=[FaultSpec("fused_query", action="hang",
                                         at_calls=(1,), delay_s=60.0)])
    eng = SearchEngine(base_x, **ENG, faults=inj)
    srv = QueryServer(eng)                # srv.faults defaults to inj
    out = srv.submit(QueryRequest(0, *_labels()))
    srv.start()
    time.sleep(0.3)                       # let the thread park on the hang
    t0 = time.monotonic()
    srv.close(drain=True)
    elapsed = time.monotonic() - t0
    r = out.get(timeout=5)
    assert r.ok                           # a hang is a delay, not a failure
    assert elapsed < 15.0, f"drain-close took {elapsed:.1f}s"
    assert srv.stats["served"] == 1
    assert srv.stats["shutdown_unserved"] == 0


def test_durability_snapshot_is_locked_pair(base_x, tmp_path):
    """summary() reads (lsn, wal stats) as ONE locked pair via
    ``SegmentedCatalog.durability_snapshot`` — a concurrent append must
    never yield an lsn from after it with stats from before."""
    eng = SearchEngine(base_x, **ENG, live=True,
                       data_dir=str(tmp_path / "cat"))
    srv = QueryServer(eng)
    cat = eng._catalog
    assert cat.durability_snapshot()["lsn"] == cat._lsn
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            s = srv.summary()["durable"]
            # wal_records counts every logged mutation; lsn is assigned
            # from it under the same lock — a torn read shows records
            # from after an append paired with the lsn from before
            if s["wal_records"] != s["lsn"]:
                torn.append(s)

    t = threading.Thread(target=reader)
    t.start()
    for i in range(100):
        eng.append(_data(2, seed=i))
    stop.set()
    t.join()
    assert torn == []
    assert srv.summary()["durable"]["lsn"] == 100
    # engines without persistence publish no durable block
    plain = SearchEngine(base_x, **ENG, live=True)
    assert plain._catalog.durability_snapshot() is None
    assert "durable" not in QueryServer(plain).summary()
    srv.close()
