"""Live catalog ingestion (ISSUE 5 / DESIGN.md §12).

Contracts pinned here:
  * MONOLITHIC PARITY: at every point of an append/delete/compact
    schedule, ranked ids AND scores of the segmented engine are bitwise
    those of a fresh monolithic ``build_index`` engine over the
    surviving rows (ids mapped through the — monotone — live-id list, so
    tie-breaks at the k-th score agree too), on both the device-ranked
    (max_results=k) and host-ranked (max_results=None) paths, including
    ragged tail segments and duplicate-row kth-score ties;
  * tombstoned rows NEVER surface: masked at score accumulation
    (kernels/ops.accumulate_scores' valid mask), dead in knn, dead on
    the scan path;
  * global ids are append-ordered and stable forever — refine() across
    an append keeps referring to the same rows;
  * snapshot/epoch discipline: compaction swaps atomically, epochs tag
    capacity hints so nothing sized for one geometry leaks into the
    next;
  * honest stats: per-segment refined-block attribution partitions the
    global figure exactly (no double-count across the virtual block
    space), live/tombstone counts are reported, segment bytes sum;
  * the QueryServer ingest path interleaves with query windows and
    counts its traffic.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import knn as knn_mod
from repro.core.engine import SearchEngine
from repro.core.segments import SegmentedCatalog
from repro.kernels import ops as kops
from repro.serve.engine import IngestRequest, QueryRequest, QueryServer

ENG = dict(n_subsets=4, subset_dim=4, block=64)


def _data(n=700, extra=300, d=16, seed=0, ties=True):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n + extra, d)).astype(np.float32)
    if ties:
        x[50:60] = x[40:50]          # duplicate rows -> kth-score ties
    return x[:n], x[n:]


def _labels(n_pos=12, n_neg=60):
    return list(range(n_pos)), list(range(100, 100 + n_neg))


def _mono(x_all, live_ids, pos, neg, k, **kw):
    """The oracle: a fresh monolithic engine over ONLY the surviving
    rows; result ids mapped back to global through the live-id list."""
    eng = SearchEngine(x_all[live_ids], **ENG, **kw)
    pc = np.searchsorted(live_ids, pos)
    nc = np.searchsorted(live_ids, neg)
    res = eng.query(pc, nc, model="dbranch", max_results=k)
    return live_ids[res.ids], res.scores


def _live_ids(engine):
    return np.nonzero(engine._catalog.snapshot().valid_host)[0]


def _assert_parity(engine, x_all, pos, neg, k):
    live_ids = _live_ids(engine)
    res = engine.query(pos, neg, model="dbranch", max_results=k)
    ids_m, sc_m = _mono(x_all, live_ids, pos, neg, k)
    np.testing.assert_array_equal(res.ids, ids_m)
    np.testing.assert_array_equal(res.scores, sc_m)
    return res


# ----------------------------------------------------------------------
# lifecycle parity (seeded)
# ----------------------------------------------------------------------

def test_append_then_delete_then_compact_parity():
    base, extra = _data()
    x_all = np.concatenate([base, extra])
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)

    _assert_parity(eng, base, pos, neg, 50)

    ids = eng.append(extra)                      # ragged delta (300 % 64)
    assert ids[0] == len(base) and len(ids) == len(extra)
    assert eng.index_stats()["n_segments"] == 2
    res = _assert_parity(eng, x_all, pos, neg, 50)

    # tombstone top hits + a delta row; never a training id
    dele = [int(i) for i in res.ids[:5]] + [int(ids[3])]
    dele = [i for i in dele if i not in pos + neg]
    nd = eng.delete(dele)
    assert nd == len(set(dele))
    res = _assert_parity(eng, x_all, pos, neg, 50)
    assert not np.intersect1d(res.ids, dele).size

    st = eng.compact()
    assert not st["skipped"] and st["merged_segments"] == 2
    assert eng.index_stats()["n_segments"] == 1
    res2 = _assert_parity(eng, x_all, pos, neg, 50)
    np.testing.assert_array_equal(res.ids, res2.ids)
    np.testing.assert_array_equal(res.scores, res2.scores)


def test_host_rank_path_and_oracle_engine_parity():
    """max_results=None (host ranking from one buffer transfer) and the
    all-oracle engine (use_fused=False -> per-segment query_index) agree
    with the fused device path after an append + delete."""
    base, extra = _data(ties=False)
    x_all = np.concatenate([base, extra])
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)
    eng.append(extra)
    eng.delete([500, 710, 711])
    dev = eng.query(pos, neg, model="dbranch", max_results=80)
    host = eng.query(pos, neg, model="dbranch", max_results=None)
    np.testing.assert_array_equal(dev.ids, host.ids[:80])
    oracle = SearchEngine(base, **ENG, live=True, use_fused=False,
                          use_jax_fit=False)
    oracle.append(extra)
    oracle.delete([500, 710, 711])
    ores = oracle.query(pos, neg, model="dbranch", max_results=None)
    np.testing.assert_array_equal(host.ids, ores.ids)
    np.testing.assert_array_equal(host.scores, ores.scores)


def test_query_batch_parity_and_generation_tagged_hints():
    base, extra = _data(ties=False)
    x_all = np.concatenate([base, extra])
    eng = SearchEngine(base, **ENG, live=True)
    reqs = [{"pos_ids": list(range(i, i + 10)),
             "neg_ids": list(range(200, 260)),
             "model": "dbranch", "max_results": 40} for i in (0, 20)]
    eng.query_batch(reqs)            # warm + populate generation-0 hints
    gen0_keys = set(eng._cap_hints)
    assert gen0_keys and all(k[0] == 0 for k in gen0_keys)
    eng.append(extra)
    # appends/deletes only EXTEND/overlay the geometry: hints survive
    # (a steady ingest workload must not re-pay cold-start capacities)
    assert gen0_keys <= set(eng._cap_hints)
    eng.delete([650])
    assert gen0_keys <= set(eng._cap_hints)
    outs = eng.query_batch(reqs)
    live_ids = _live_ids(eng)
    for req, out in zip(reqs, outs):
        ids_m, sc_m = _mono(x_all, live_ids, req["pos_ids"],
                            req["neg_ids"], 40)
        np.testing.assert_array_equal(out.ids, ids_m)
        np.testing.assert_array_equal(out.scores, sc_m)
    # compaction REPLACES the geometry: generation-0 hints are void and
    # pruned — no leakage into the re-sorted block space
    eng.compact()
    assert all(k[0] == 1 for k in eng._cap_hints)
    eng.query_batch(reqs)
    assert any(k[0] == 1 for k in eng._cap_hints)


def test_hint_pruning_across_generations_at_large_delta_fraction():
    """Capacity-hint pruning under heavy ingest (ISSUE 6 satellite): a
    catalog whose deltas dominate the base (delta fraction > 50%) run
    through TWO compaction generations. Hints must be (re)learned per
    generation, pruned the moment their geometry dies, and the table
    must never accumulate keys from dead generations — while ranked
    parity with the monolithic oracle holds at every step."""
    rng = np.random.default_rng(9)
    base = rng.normal(0, 1, (400, 16)).astype(np.float32)
    d1 = rng.normal(0, 1, (500, 16)).astype(np.float32)
    d2 = rng.normal(0, 1, (400, 16)).astype(np.float32)
    x_all = np.concatenate([base, d1, d2])
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)
    eng.query(pos, neg, model="dbranch", max_results=40)

    eng.append(d1)                       # delta fraction 500/900
    eng.delete([700, 705])
    eng.query(pos, neg, model="dbranch", max_results=40)
    keys_g0 = set(eng._cap_hints)
    assert keys_g0 and all(k[0] == 0 for k in keys_g0)
    _assert_parity(eng, np.concatenate([base, d1]), pos, neg, 40)

    eng.compact()                        # generation 1: gen-0 keys die
    assert all(k[0] == 1 for k in eng._cap_hints)
    eng.append(d2)                       # delta fraction 400/1300 on gen 1
    eng.query(pos, neg, model="dbranch", max_results=40)
    assert eng._cap_hints and all(k[0] == 1 for k in eng._cap_hints)
    _assert_parity(eng, x_all, pos, neg, 40)

    eng.compact()                        # generation 2: gen-1 keys die
    assert all(k[0] == 2 for k in eng._cap_hints)
    eng.query(pos, neg, model="dbranch", max_results=40)
    assert eng._cap_hints and all(k[0] == 2 for k in eng._cap_hints)
    # the table holds exactly ONE live generation — no leakage, bounded
    # growth on a long-running server
    assert len({k[0] for k in eng._cap_hints}) == 1
    _assert_parity(eng, x_all, pos, neg, 40)


def test_refine_id_stability_across_append():
    """Paper §5 refinement across an ingest: extra labels found BEFORE an
    append keep identifying the same rows after it (global ids are
    append-ordered and stable), and the refined result equals the
    monolithic engine over the grown catalog."""
    base, extra = _data(ties=False)
    x_all = np.concatenate([base, extra])
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)
    first = eng.query(pos, neg, model="dbranch", max_results=30)
    extra_pos = [int(first.ids[0])]
    extra_neg = [int(first.ids[-1])]
    eng.append(extra)
    ref = eng.refine(first, extra_pos, extra_neg, pos, neg, max_results=30)
    ids_m, sc_m = _mono(x_all, np.arange(len(x_all)), pos + extra_pos,
                        neg + extra_neg, 30)
    np.testing.assert_array_equal(ref.ids, ids_m)
    np.testing.assert_array_equal(ref.scores, sc_m)


def test_scan_and_knn_paths_respect_tombstones():
    base, extra = _data(ties=False)
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)
    ids = eng.append(extra)
    probe = eng.query(pos, neg, model="dtree", max_results=None)
    dele = [int(i) for i in probe.ids[:3]] + [int(ids[0])]
    eng.delete(dele)
    for model in ("dtree", "knn"):
        res = eng.query(pos, neg, model=model, max_results=None)
        assert not np.intersect1d(res.ids, dele).size, model


def test_knn_segmented_matches_bruteforce_over_live_rows():
    base, extra = _data(ties=False)
    x_all = np.concatenate([base, extra])
    eng = SearchEngine(base, **ENG, live=True)
    eng.append(extra)
    eng.delete(list(range(60, 90)) + [701, 702])
    snap = eng._catalog.snapshot()
    live_ids = np.nonzero(snap.valid_host)[0]
    queries = x_all[[5, 300, 720]]
    k = 25
    ids_k, d_k = knn_mod.knn_subset(snap.indexes[0], queries, k=k,
                                    live=snap.valid_host)
    dims = snap.indexes[0].dims
    xl = x_all[live_ids][:, dims]
    qd = ((xl[None, :, :] - queries[:, None, dims]) ** 2).sum(-1)
    order = np.lexsort(
        (np.broadcast_to(live_ids, qd.shape), qd), axis=1)[:, :k]
    np.testing.assert_array_equal(ids_k, live_ids[order])


# ----------------------------------------------------------------------
# parity under ARBITRARY schedules (seeded always; hypothesis when
# available widens the net)
# ----------------------------------------------------------------------

def _run_schedule(seed: int, n0: int, ops):
    """Drive one append/delete/compact schedule and assert monolithic
    parity (ids AND scores, device-ranked path) after EVERY op."""
    rng = np.random.default_rng(seed)
    d = 10
    x_all = rng.normal(0, 1, (n0 + 4 * 80, d)).astype(np.float32)
    x_all[30:36] = x_all[24:30]            # kth-score tie fodder
    pos = list(rng.choice(n0 // 2, 8, replace=False))
    neg = [int(v) for v in
           rng.choice(np.arange(n0 // 2, n0), 30, replace=False)]
    eng = SearchEngine(x_all[:n0], **ENG, live=True)
    cursor = n0
    for op in ops:
        if op == "append":
            m = int(rng.integers(1, 80))   # ragged tails (m % 64)
            eng.append(x_all[cursor:cursor + m])
            cursor += m
        elif op == "delete":
            snap = eng._catalog.snapshot()
            cand = np.nonzero(snap.valid_host)[0]
            cand = cand[~np.isin(cand, pos + neg)]
            if len(cand) > 20:
                eng.delete(rng.choice(cand, 15, replace=False))
        else:
            eng.compact()
        live_ids = _live_ids(eng)
        res = eng.query(pos, neg, model="dbranch", max_results=25)
        ids_m, sc_m = _mono(x_all[:cursor], live_ids, pos, neg, 25)
        np.testing.assert_array_equal(res.ids, ids_m)
        np.testing.assert_array_equal(res.scores, sc_m)


@pytest.mark.parametrize("seed,ops", [
    (1, ["append", "delete", "append", "compact"]),
    (2, ["delete", "compact", "append"]),
    (3, ["append", "append", "append", "delete", "compact", "delete"]),
])
def test_schedule_parity_seeded(seed, ops):
    _run_schedule(seed, 200 + 13 * seed, ops)


def test_schedule_parity_hypothesis():
    pytest.importorskip(
        "hypothesis",
        reason="dev dependency (pip install -r requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def schedules(draw):
        seed = draw(st.integers(0, 2 ** 31 - 1))
        n0 = draw(st.integers(150, 300))
        ops = draw(st.lists(
            st.sampled_from(["append", "delete", "compact"]),
            min_size=1, max_size=4))
        return seed, n0, ops

    @settings(max_examples=8, deadline=None)
    @given(schedules())
    def run(sched):
        _run_schedule(*sched)

    run()


# ----------------------------------------------------------------------
# honest stats + masked kernels
# ----------------------------------------------------------------------

def test_segment_stats_honest_accounting():
    base, extra = _data(ties=False)
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)
    ids = eng.append(extra)
    eng.delete(ids[:10])
    st = eng.index_stats()
    assert st["live"] and st["n_segments"] == 2
    assert st["rows_live"] == len(base) + len(extra) - 10
    assert st["rows_tombstoned"] == 10
    segs = st["segments"]
    # per-segment rows/bytes partition the catalog exactly
    assert sum(s["rows"] for s in segs) == st["rows"]
    assert sum(s["rows_tombstoned"] for s in segs) == 10
    assert sum(s["bytes"] for s in segs) == st["index_bytes"]
    # fused stats: per-segment refined blocks partition the global
    # figure over the virtual block space — no double-count
    res = eng.query(pos, neg, model="dbranch", max_results=40)
    qs = res.stats
    assert qs["n_segments"] == 2
    assert qs["rows_live"] == st["rows_live"]
    assert qs["rows_tombstoned"] == 10
    per_seg = qs["per_segment_blocks_touched"]
    assert len(per_seg) == 2 and sum(per_seg) == qs["blocks_touched"]
    assert qs["blocks_gathered"] >= qs["blocks_touched"]


def test_masked_accumulate_and_rank_under_tombstones():
    """Kernel-level: accumulate_scores' valid mask zeroes exactly the
    tombstoned rows' counts, and rank_topk with the query's score_bound
    stays exact down to the all-dead edge (n_valid == 0)."""
    rng = np.random.default_rng(0)
    n, block, nb, q = 256, 32, 8, 3
    counts = jnp.asarray(rng.integers(0, 5, (nb, block, q)), jnp.int32)
    cand = jnp.arange(nb, dtype=jnp.int32)
    inv = jnp.asarray(rng.permutation(n), jnp.int32)
    valid = rng.integers(0, 2, n).astype(np.int32)
    base = np.asarray(kops.accumulate_scores(
        jnp.zeros((n, q), jnp.int32), counts, cand, inv, nb=nb))
    masked = np.asarray(kops.accumulate_scores(
        jnp.zeros((n, q), jnp.int32), counts, cand, inv,
        jnp.asarray(valid), nb=nb))
    np.testing.assert_array_equal(masked, base * valid[:, None])
    # ranking the masked buffer never surfaces a dead row, for every
    # rank method, with the true score bound
    bound = int(base.max())
    tids = jnp.full((q, 4), n, jnp.int32)
    for method in ("threshold", "sort", "topk"):
        ids_k, sc_k, nv = kops.rank_topk(
            jnp.asarray(masked.T), tids, k=16, score_bound=bound,
            method=method)
        ids_k = np.asarray(ids_k)
        assert not np.isin(ids_k[ids_k >= 0],
                           np.nonzero(valid == 0)[0]).any(), method
    # all-dead edge: every query comes back empty, no crash
    ids_k, sc_k, nv = kops.rank_topk(
        jnp.zeros((q, n), jnp.int32), tids, k=16, score_bound=bound)
    assert (np.asarray(nv) == 0).all() and (np.asarray(ids_k) == -1).all()


# ----------------------------------------------------------------------
# composition + lifecycle edges
# ----------------------------------------------------------------------

def test_live_with_shards_flat_fallback_parity():
    """n_shards > 1 composition (flat fallback): the base is ceil-split
    into per-shard segments, deltas land on per-shard tails, and results
    stay bitwise the monolithic oracle's."""
    base, extra = _data(ties=False)
    x_all = np.concatenate([base, extra])
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True, n_shards=2)
    assert eng.index_stats()["n_segments"] == 2      # ceil-split base
    eng.append(extra[:100])
    eng.append(extra[100:])
    shards = [s["shard"] for s in eng.index_stats()["segments"]]
    assert sorted(set(shards)) == [0, 1]             # per-shard tails
    _assert_parity(eng, x_all, pos, neg, 50)


def test_background_compact_swaps_atomically():
    base, extra = _data(ties=False)
    x_all = np.concatenate([base, extra])
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)
    eng.append(extra)
    before = eng.query(pos, neg, model="dbranch", max_results=50)
    t = eng.compact(background=True)
    t.join(timeout=30)
    assert not t.is_alive()
    assert eng.index_stats()["n_segments"] == 1
    after = _assert_parity(eng, x_all, pos, neg, 50)
    np.testing.assert_array_equal(before.ids, after.ids)


def test_lifecycle_guards():
    base, extra = _data(ties=False)
    static = SearchEngine(base, **ENG)
    with pytest.raises(RuntimeError, match="live=True"):
        static.append(extra)
    eng = SearchEngine(base, **ENG, live=True)
    with pytest.raises(ValueError, match="width"):
        eng.append(extra[:, :4])
    with pytest.raises(ValueError, match="range"):
        eng.delete([len(base) + 5])
    assert eng.append(extra[:0]).size == 0           # no-op, no epoch
    assert eng.index_stats()["epoch"] == 0
    assert eng.delete([]) == 0
    assert eng.delete([3, 3, 3]) == 1                # idempotent dedup
    assert eng.delete([3]) == 0
    assert eng.compact()["skipped"]                  # single segment


def test_catalog_snapshot_isolation():
    """An in-flight reader's snapshot is untouched by later mutations —
    the epoch discipline at the SegmentedCatalog level."""
    base, extra = _data(ties=False)
    cat = SegmentedCatalog(base, SearchEngine(base, **ENG).subsets,
                           block=64)
    snap0 = cat.snapshot()
    cat.append(extra)
    cat.delete([0, 1])
    cat.compact()
    assert snap0.epoch == 0 and snap0.n == len(base)
    assert snap0.valid_host.all()
    assert len(snap0.segments) == 1
    assert cat.snapshot().epoch == 3
    assert cat.snapshot().n == len(base) + len(extra)


# ----------------------------------------------------------------------
# serving: ingest interleaves with query windows
# ----------------------------------------------------------------------

def test_server_ingest_interleaves_with_queries():
    base, extra = _data(ties=False)
    x_all = np.concatenate([base, extra])
    pos, neg = _labels()
    eng = SearchEngine(base, **ENG, live=True)
    server = QueryServer(eng, max_batch=4, batch_window_s=0.01,
                         max_results=40)
    server.start()
    try:
        q0 = server.submit(QueryRequest(0, pos, neg))
        a1 = server.submit(IngestRequest(1, "append", features=extra))
        q2 = server.submit(QueryRequest(2, pos, neg))
        r0, ra, r2 = q0.get(timeout=30), a1.get(timeout=30), \
            q2.get(timeout=30)
        assert r0.ok and ra.ok and r2.ok
        assert ra.info["op"] == "append" and ra.info["rows"] == len(extra)
        # the post-ingest query sees the grown catalog
        ids_m, _ = _mono(x_all, np.arange(len(x_all)), pos, neg, 40)
        np.testing.assert_array_equal(r2.result.ids, ids_m)
        rd = server.submit(IngestRequest(3, "delete",
                                         ids=[int(ids_m[0])])).get(30)
        assert rd.ok and rd.info["rows"] == 1
        # compaction is dispatched OFF the serving loop (queries keep
        # flowing on the old snapshot) — the ack returns immediately and
        # the swap lands when the background merge finishes
        rc = server.submit(IngestRequest(4, "compact")).get(30)
        assert rc.ok and rc.info["background"]
        server._compact_thread.join(timeout=30)
        assert eng.index_stats()["n_segments"] == 1
        bad = server.submit(IngestRequest(5, "garble")).get(30)
        assert not bad.ok
        s = server.summary()
        assert s["ingests"] == 4 and s["ingest_errors"] == 1
        assert s["rows_appended"] == len(extra)
        assert s["rows_deleted"] == 1 and s["compactions"] == 1
        assert s["live"] and s["epoch"] == 3
        assert s["served"] == 2 and s["errors"] == 0
    finally:
        server.close()
