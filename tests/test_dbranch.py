"""DBranch / DBEns unit tests (numpy + JAX trainers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.boxes import BoxSet
from repro.core.dbranch import (fit_dbens, fit_dbranch,
                                fit_dbranch_best_subset, fit_dbranch_jax,
                                predict_boxes_jax)
from repro.core.subsets import make_subsets


def test_fit_dbranch_separable(blob_data):
    x, y = blob_data
    xp, xn = x[y == 1], x[y == 0]
    bs = fit_dbranch(xp, xn, np.arange(x.shape[1]), max_depth=12)
    assert bs.n_boxes >= 1
    assert (bs.contains(xp) > 0).all()
    assert (bs.contains(xn) == 0).all()


def test_fit_dbranch_generalizes(blob_data):
    """Box expansion should capture unseen positives from the same cluster."""
    x, y = blob_data
    rng = np.random.default_rng(1)
    pos_idx = np.nonzero(y == 1)[0]
    train_pos = pos_idx[:30]
    test_pos = pos_idx[30:]
    xn = x[y == 0][:100]
    bs = fit_dbranch(x[train_pos], xn, np.arange(x.shape[1]))
    recall = (bs.contains(x[test_pos]) > 0).mean()
    assert recall > 0.5, f"expanded boxes should find unseen positives, got {recall}"


def test_best_subset_is_answerable(blob_data):
    x, y = blob_data
    subsets = make_subsets(x.shape[1], n_subsets=8, subset_dim=4, seed=0)
    bs = fit_dbranch_best_subset(x[y == 1], x[y == 0], subsets)
    assert 0 <= bs.subset_id < len(subsets)
    np.testing.assert_array_equal(bs.dims, subsets[bs.subset_id])


def test_dbens_box_count_and_subsets(blob_data):
    x, y = blob_data
    subsets = make_subsets(x.shape[1], n_subsets=8, subset_dim=4, seed=0)
    models = fit_dbens(x[y == 1], x[y == 0], subsets, n_models=5, seed=1)
    assert len(models) == 5
    for m in models:
        assert m.subset_id >= 0
        np.testing.assert_array_equal(m.dims, subsets[m.subset_id])


def test_dbens_improves_recall_over_single(blob_data):
    x, y = blob_data
    rng = np.random.default_rng(2)
    pos_idx = np.nonzero(y == 1)[0]
    train_pos, test_pos = pos_idx[:25], pos_idx[25:]
    xn = x[y == 0][:150]
    subsets = make_subsets(x.shape[1], n_subsets=10, subset_dim=4, seed=3)
    single = fit_dbranch_best_subset(x[train_pos], xn, subsets)
    ens = fit_dbens(x[train_pos], xn, subsets, n_models=15, seed=3)
    r1 = (single.contains(x[test_pos]) > 0).mean()
    cnt = np.zeros(len(test_pos))
    for m in ens:
        cnt += m.contains(x[test_pos])
    r2 = (cnt > 0).mean()
    assert r2 >= r1 - 1e-9


# ----------------------------------------------------------------------
# JAX trainer
# ----------------------------------------------------------------------

def _jax_boxes(xp, xn, max_nodes=64, max_depth=12, expand=True):
    frange_lo = np.minimum(xp.min(0), xn.min(0) if len(xn) else xp.min(0))
    frange_hi = np.maximum(xp.max(0), xn.max(0) if len(xn) else xp.max(0))
    lo, hi, valid = fit_dbranch_jax(
        jnp.asarray(xp), jnp.asarray(xn), jnp.asarray(frange_lo),
        jnp.asarray(frange_hi), max_nodes=max_nodes, max_depth=max_depth,
        expand=expand)
    return np.asarray(lo), np.asarray(hi), np.asarray(valid)


def test_jax_trainer_invariants(blob_data):
    x, y = blob_data
    xp = x[y == 1][:, :6]
    xn = x[y == 0][:80, :6]
    lo, hi, valid = _jax_boxes(xp, xn)
    assert valid.any()
    pred_p = np.asarray(predict_boxes_jax(jnp.asarray(xp), jnp.asarray(lo),
                                          jnp.asarray(hi), jnp.asarray(valid)))
    pred_n = np.asarray(predict_boxes_jax(jnp.asarray(xn), jnp.asarray(lo),
                                          jnp.asarray(hi), jnp.asarray(valid)))
    assert (pred_p > 0).all(), "JAX trainer must cover training positives"
    assert (pred_n == 0).all(), "JAX trainer must exclude training negatives"


def test_jax_trainer_matches_numpy_on_training_predictions():
    rng = np.random.default_rng(11)
    xp = rng.normal(1.0, 0.4, (12, 4)).astype(np.float32)
    xn = rng.normal(0.0, 1.0, (40, 4)).astype(np.float32)
    xq = rng.normal(0.5, 1.0, (200, 4)).astype(np.float32)
    bs = fit_dbranch(xp, xn, np.arange(4), max_depth=10)
    lo, hi, valid = _jax_boxes(xp, xn, max_depth=10)
    pred_np = bs.contains(xq) > 0
    pred_jx = np.asarray(predict_boxes_jax(
        jnp.asarray(xq), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(valid))) > 0
    # same algorithm, same splits -> identical decision regions
    agreement = (pred_np == pred_jx).mean()
    assert agreement > 0.97, f"agreement {agreement}"


def test_jax_trainer_vmaps_over_ensemble():
    rng = np.random.default_rng(5)
    E, P, Ng, d = 4, 8, 30, 3
    xps = rng.normal(1.0, 0.3, (E, P, d)).astype(np.float32)
    xns = rng.normal(0.0, 1.0, (E, Ng, d)).astype(np.float32)
    flo = np.full((E, d), -3.0, np.float32)
    fhi = np.full((E, d), 3.0, np.float32)
    lo, hi, valid = jax.vmap(
        lambda a, b, c, e: fit_dbranch_jax(a, b, c, e, max_nodes=32))(
        jnp.asarray(xps), jnp.asarray(xns), jnp.asarray(flo), jnp.asarray(fhi))
    assert lo.shape == (E, 32, d)
    assert np.asarray(valid).any(axis=1).all()


def test_no_negatives_trivial_box():
    xp = np.asarray([[0.5, 0.5], [0.7, 0.6]], np.float32)
    xn = np.zeros((0, 2), np.float32)
    bs = fit_dbranch(xp, xn, np.arange(2))
    assert bs.n_boxes == 1
    assert (bs.contains(xp) > 0).all()
