"""Shared fixtures. NOTE: no XLA device-count flags here — tests must see
the real host device (the 512-device override belongs to dryrun.py only).
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def blob_data():
    """Separable 2-class blobs in 16-d: class 1 concentrated, class 0
    spread — the canonical search-by-classification setting."""
    r = np.random.default_rng(42)
    n_pos, n_neg, d = 60, 400, 16
    pos = r.normal(2.0, 0.3, (n_pos, d)).astype(np.float32)
    neg = r.normal(0.0, 1.0, (n_neg, d)).astype(np.float32)
    x = np.concatenate([pos, neg])
    y = np.concatenate([np.ones(n_pos), np.zeros(n_neg)]).astype(np.int32)
    return x, y


@pytest.fixture(scope="session")
def catalog():
    """A small synthetic patch catalog with features + labels."""
    from repro.data.synthetic import (PatchDatasetConfig, generate_patches,
                                      handcrafted_features)
    data = generate_patches(PatchDatasetConfig(n_patches=1500, seed=3))
    feats = handcrafted_features(data["images"])
    return feats, data["labels"]
