"""Observability layer (ISSUE 10 / DESIGN.md §17): metrics registry,
profiling hooks, cache instrumentation, and summary() aliasing.

Contracts pinned here:
  * Counter / Gauge / Histogram primitives: typed registration (name
    collisions across kinds fail loudly; same name+kind returns the
    shared instance), label children, fixed-bucket quantiles derivable
    without stored samples;
  * ``render_prometheus`` emits valid text exposition v0.0.4 — every
    sample line parses, histograms carry cumulative ``_bucket{le=}`` +
    ``_sum`` + ``_count``, HELP/TYPE headers come once per family;
  * scrape-time collectors: one locked counter dict published through
    ``register_collector`` with no hot-path double bookkeeping, and a
    collector that throws surfaces as ``obs_collector_errors`` instead
    of killing the scrape;
  * ``obs.profile``: thread-bound registry, global enable switch, sites
    land in ``profile_seconds{site=}``;
  * ResultCache: per-entry hit counts, age-at-eviction histogram, and
    ``cache_*`` metrics via ``attach`` — same numbers as ``stats()``;
  * ``QueryServer.summary()`` returns SNAPSHOTS: mutating a returned
    nested dict (recovery report, durability block) must not write
    through to live server state.
"""
import re
import threading

import numpy as np
import pytest

from repro.obs import Observability
from repro.obs import profile as obs_profile
from repro.obs.metrics import (AGE_BUCKETS_S, Counter, Gauge, Histogram,
                               LATENCY_BUCKETS_S, MetricsRegistry,
                               default_registry)
from repro.serve.cache import ResultCache


# ----------------------------------------------------------------------
# registry primitives
# ----------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(4)
    assert c.value == 5.0
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.inc(2)
    g.dec()
    assert g.value == 8.0


def test_counter_labels_are_independent_children():
    reg = MetricsRegistry()
    c = reg.counter("hits_total", "hits", labelnames=("route",))
    c.inc(1, route="/query")
    c.inc(2, route="/stats")
    assert reg.value("hits_total", route="/query") == 1.0
    assert reg.value("hits_total", route="/stats") == 2.0


def test_register_same_name_same_kind_returns_shared_instance():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x")
    b = reg.counter("x_total", "x")
    assert a is b
    a.inc()
    assert b.value == 1.0


def test_register_kind_mismatch_fails_loudly():
    reg = MetricsRegistry()
    reg.counter("thing", "x")
    with pytest.raises((TypeError, ValueError)):
        reg.gauge("thing", "x")


def test_histogram_quantiles_without_stored_samples():
    h = Histogram("lat_seconds", "latency", buckets=LATENCY_BUCKETS_S)
    rng = np.random.default_rng(0)
    xs = rng.uniform(0.001, 0.5, size=2000)
    for x in xs:
        h.observe(float(x))
    for q in (0.5, 0.99):
        est = h.quantile(q)
        true = float(np.quantile(xs, q))
        # bucket-interpolated: correct to within the bucket's width
        lo = max(b for b in LATENCY_BUCKETS_S if b <= true)
        hi = min(b for b in LATENCY_BUCKETS_S if b >= true)
        assert lo * 0.99 <= est <= hi * 1.01, (q, est, true)
    assert h.count == 2000
    assert h.sum == pytest.approx(float(xs.sum()), rel=1e-6)


def test_histogram_empty_and_overflow_bucket():
    h = Histogram("h_seconds", "h", buckets=(0.01, 0.1))
    assert h.quantile(0.5) == 0.0
    h.observe(5.0)              # beyond the last bound -> +Inf bucket
    # the +Inf bucket has no upper edge; quantiles report its lower bound
    assert h.quantile(0.99) == pytest.approx(0.1)


# ----------------------------------------------------------------------
# Prometheus text exposition
# ----------------------------------------------------------------------

# one sample line: name{labels} value  (labels optional)
_SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"(,[a-zA-Z_][a-zA-Z0-9_]*='
    r'"[^"]*")*\})? '
    r'[-+]?([0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?|Inf|NaN)$')


def _assert_valid_exposition(text: str) -> None:
    families = {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# HELP") or line.startswith("# TYPE"):
            parts = line.split(None, 3)
            assert len(parts) >= 3, line
            if parts[1] == "TYPE":
                # TYPE comes at most once per family
                assert parts[2] not in families, line
                families[parts[2]] = parts[3]
            continue
        assert _SAMPLE_RE.match(line), f"unparseable sample: {line!r}"


def test_render_prometheus_is_valid_and_complete():
    reg = MetricsRegistry()
    reg.counter("a_total", "a counter").inc(3)
    reg.gauge("b_gauge", "a gauge", labelnames=("x",)).set(1.5, x="y")
    h = reg.histogram("c_seconds", "a histogram", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.render_prometheus()
    _assert_valid_exposition(text)
    # cumulative buckets: le="0.1" < le="1" < le="+Inf" == count
    m = {ln.split(" ")[0]: float(ln.split(" ")[1])
         for ln in text.splitlines()
         if ln and not ln.startswith("#")}
    assert m['c_seconds_bucket{le="0.1"}'] == 1
    assert m['c_seconds_bucket{le="1"}'] == 2
    assert m['c_seconds_bucket{le="+Inf"}'] == 3
    assert m["c_seconds_count"] == 3
    assert m["c_seconds_sum"] == pytest.approx(5.55)
    assert m["a_total"] == 3


def test_collector_publishes_external_counters():
    reg = MetricsRegistry()
    ledger = {"served": 0}

    def collect():
        yield ("srv_served_total", "counter", {}, ledger["served"])

    reg.register_collector(collect)
    ledger["served"] = 42
    assert reg.value("srv_served_total") == 42.0
    assert "srv_served_total 42" in reg.render_prometheus()


def test_broken_collector_does_not_kill_scrape():
    reg = MetricsRegistry()
    reg.counter("ok_total", "fine").inc()

    def broken():
        raise RuntimeError("boom")
        yield  # pragma: no cover

    reg.register_collector(broken)
    text = reg.render_prometheus()
    _assert_valid_exposition(text)
    assert "ok_total 1" in text
    assert "obs_collector_errors" in text


# ----------------------------------------------------------------------
# profiling hooks
# ----------------------------------------------------------------------

def test_profile_records_into_bound_registry():
    reg = MetricsRegistry()
    prev = obs_profile.enabled()
    obs_profile.set_enabled(True)
    try:
        with obs_profile.bind_registry(reg):
            with obs_profile.profile("device_sync"):
                pass
            obs_profile.record("jit_dispatch", 0.25)
        assert reg.value("profile_seconds_count", site="device_sync") == 1
        assert reg.value("profile_seconds_sum",
                         site="jit_dispatch") == pytest.approx(0.25)
    finally:
        obs_profile.set_enabled(prev)


def test_profile_disabled_is_noop():
    reg = MetricsRegistry()
    prev = obs_profile.enabled()
    obs_profile.set_enabled(False)
    try:
        with obs_profile.bind_registry(reg):
            with obs_profile.profile("device_sync"):
                pass
            obs_profile.record("wal_fsync", 1.0)
        assert reg.value("profile_seconds_count", site="device_sync") == 0
        assert reg.value("profile_seconds_sum", site="wal_fsync") == 0.0
    finally:
        obs_profile.set_enabled(prev)


def test_profile_binding_is_per_thread():
    reg_a, reg_b = MetricsRegistry(), MetricsRegistry()
    prev = obs_profile.enabled()
    obs_profile.set_enabled(True)
    done = threading.Event()

    def other():
        with obs_profile.bind_registry(reg_b):
            obs_profile.record("compact", 1.0)
        done.set()

    try:
        with obs_profile.bind_registry(reg_a):
            t = threading.Thread(target=other)
            t.start()
            t.join()
            obs_profile.record("compact", 2.0)
        assert done.is_set()
        assert reg_a.value("profile_seconds_sum",
                           site="compact") == pytest.approx(2.0)
        assert reg_b.value("profile_seconds_sum",
                           site="compact") == pytest.approx(1.0)
    finally:
        obs_profile.set_enabled(prev)


def test_default_registry_is_a_singleton():
    assert default_registry() is default_registry()


# ----------------------------------------------------------------------
# cache instrumentation (satellite b)
# ----------------------------------------------------------------------

class _Res:
    def __init__(self, nbytes=100):
        self.ids = np.zeros(nbytes // 8, dtype=np.int64)
        self.scores = np.zeros(0, dtype=np.float32)


def test_cache_per_entry_hits_and_report():
    c = ResultCache(max_bytes=1 << 20, max_entries=16)
    k1 = ("a",) + (0, 0)
    k2 = ("b",) + (0, 0)
    c.put(k1, _Res())
    c.put(k2, _Res())
    for _ in range(3):
        assert c.get(k1) is not None
    assert c.get(k2) is not None
    rep = c.entry_report(10)
    assert [r["hits"] for r in rep] == [3, 1]
    assert all(r["age_s"] >= 0 and r["nbytes"] > 0 for r in rep)


def test_cache_age_histogram_and_registry_attach():
    reg = MetricsRegistry()
    c = ResultCache(max_bytes=1 << 20, max_entries=2)
    c.attach(reg)
    c.put(("a",) + (0, 0), _Res())
    c.put(("b",) + (0, 0), _Res())
    c.get(("a",) + (0, 0))
    c.put(("c",) + (0, 0), _Res())   # evicts LRU tail -> one age sample
    assert reg.value("cache_age_at_eviction_seconds_count") == 1
    assert c.age_at_eviction_quantile(0.5) >= 0.0
    # scrape and stats() agree — one source of truth
    st = c.stats()
    assert reg.value("cache_hits_total") == st["hits"] == 1
    assert reg.value("cache_evictions_total") == st["evictions"] == 1
    assert reg.value("cache_entries") == len(c) == 2
    assert reg.value("cache_hit_rate") == pytest.approx(st["hit_rate"])
    _assert_valid_exposition(reg.render_prometheus())


def test_cache_stale_invalidation_records_ages():
    reg = MetricsRegistry()
    c = ResultCache()
    c.attach(reg)
    c.put(("a",) + (0, 0), _Res())
    c.put(("b",) + (1, 0), _Res())
    dropped = c.invalidate_epoch(1, 0)
    assert dropped == 1
    assert reg.value("cache_age_at_eviction_seconds_count") == 1
    assert reg.value("cache_stale_evictions_total") == 1


# ----------------------------------------------------------------------
# summary() snapshot isolation (satellite a) + obs block
# ----------------------------------------------------------------------

ENG = dict(n_subsets=4, subset_dim=4, block=64)


def _data(n=300, d=16, seed=0):
    return np.random.default_rng(seed).normal(
        0, 1, (n, d)).astype(np.float32)


def test_summary_returns_snapshots_not_live_references(tmp_path):
    from repro.core.engine import SearchEngine
    from repro.serve.engine import QueryServer
    eng = SearchEngine(_data(), **ENG, live=True,
                       data_dir=str(tmp_path / "cat"), wal_sync="always")
    srv = QueryServer(eng, max_results=10)
    try:
        s1 = srv.summary()
        assert "durable" in s1
        # mutate everything nested the caller can reach; the server's
        # next summary must be unaffected
        for k in list(s1["durable"]):
            s1["durable"][k] = "poisoned"
        if "recovery" in s1:
            for k in list(s1["recovery"]):
                s1["recovery"][k] = "poisoned"
        s2 = srv.summary()
        assert all(v != "poisoned" for v in s2["durable"].values())
        if "recovery" in s2:
            assert all(v != "poisoned"
                       for v in s2["recovery"].values())
    finally:
        srv.close()


def test_summary_carries_obs_block_and_latency_quantiles():
    from repro.core.engine import SearchEngine
    from repro.serve.engine import QueryServer
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=10)
    try:
        r = srv.handle(_mk_req(srv))
        assert r.ok
        s = srv.summary()
        assert s["obs"]["metrics_enabled"] is True
        assert s["obs"]["tracing_enabled"] is True
        assert s["obs"]["latency_p50_s"] > 0.0
        assert s["obs"]["traces_buffered"] >= 1
    finally:
        srv.close()


def _mk_req(srv):
    from repro.serve.engine import QueryRequest
    req = QueryRequest(1, list(range(8)), list(range(50, 80)), "dbranch")
    req.trace = srv.obs.new_trace()
    return req


def test_observability_disabled_creates_no_traces():
    obs = Observability(metrics_enabled=False, tracing_enabled=False)
    assert obs.new_trace() is None
    assert len(obs.traces) == 0
