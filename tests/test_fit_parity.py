"""Device-trainer parity suite (DESIGN.md §10).

The contract under test: the JAX trainer (fit_dbranch_jax and the
batched fit_select_jax the engine serves with) produces BITWISE the same
boxes as the numpy oracle — same midpoint splits, same float32 Gini
scores, same expansion limits, same feature_range — so the oracle stays
a usable reference for the production device path. Plus the invariants
the device selection relies on: every training positive sits in an
emitted leaf (fn == 0), and expanded boxes never swallow an excluded
negative.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dbranch import (dbens_draws, fit_dbens, fit_dbranch,
                                fit_dbranch_best_subset, fit_dbranch_jax,
                                split_tables)
from repro.core.engine import SearchEngine
from repro.core.subsets import make_subsets


def _sorted_boxes(lo, hi):
    lo, hi = np.asarray(lo), np.asarray(hi)
    key = np.lexsort(np.concatenate([lo, hi], 1).T[::-1])
    return lo[key], hi[key]


def _assert_same_boxes(bs, lo, hi, valid):
    """Box SET equality, bitwise (the trainers emit in different orders:
    numpy DFS vs worklist BFS)."""
    lo, hi, valid = np.asarray(lo), np.asarray(hi), np.asarray(valid)
    a_lo, a_hi = _sorted_boxes(bs.lo, bs.hi)
    b_lo, b_hi = _sorted_boxes(lo[valid], hi[valid])
    assert a_lo.shape == b_lo.shape, (a_lo.shape, b_lo.shape)
    np.testing.assert_array_equal(a_lo, b_lo)
    np.testing.assert_array_equal(a_hi, b_hi)


def _rand_case(seed):
    rng = np.random.default_rng(seed)
    p = int(rng.integers(3, 40))
    ng = int(rng.integers(5, 120))
    d = int(rng.integers(2, 7))
    xp = rng.normal(1.0, 0.5, (p, d)).astype(np.float32)
    xn = rng.normal(0.0, 1.0, (ng, d)).astype(np.float32)
    flo = (np.minimum(xp.min(0), xn.min(0)) - 1).astype(np.float32)
    fhi = (np.maximum(xp.max(0), xn.max(0)) + 1).astype(np.float32)
    return xp, xn, flo, fhi


@pytest.mark.parametrize("seed", range(12))
def test_jax_boxes_bitwise_match_numpy(seed):
    """Same splits -> same boxes, including expansion and feature_range."""
    xp, xn, flo, fhi = _rand_case(seed)
    d = xp.shape[1]
    bs = fit_dbranch(xp, xn, np.arange(d), max_depth=10,
                     feature_range=(flo, fhi))
    lo, hi, valid = fit_dbranch_jax(
        jnp.asarray(xp), jnp.asarray(xn), jnp.asarray(flo),
        jnp.asarray(fhi), max_nodes=128, max_depth=10)
    _assert_same_boxes(bs, lo, hi, valid)


@pytest.mark.parametrize("seed", (0, 3, 7))
def test_padded_masked_fit_matches_unpadded(seed):
    """pow2-padded rows with validity masks change nothing (the batched
    engine path always trains on padded lanes)."""
    xp, xn, flo, fhi = _rand_case(seed)
    p, ng, d = len(xp), len(xn), xp.shape[1]
    lo1, hi1, v1 = fit_dbranch_jax(
        jnp.asarray(xp), jnp.asarray(xn), jnp.asarray(flo),
        jnp.asarray(fhi), max_nodes=64)
    pp, np_ = 64, 128
    xpp = np.zeros((pp, d), np.float32)
    xpp[:p] = xp
    xnp = np.zeros((np_, d), np.float32)
    xnp[:ng] = xn
    lo2, hi2, v2 = fit_dbranch_jax(
        jnp.asarray(xpp), jnp.asarray(xnp), jnp.asarray(flo),
        jnp.asarray(fhi), jnp.asarray(np.arange(pp) < p),
        jnp.asarray(np.arange(np_) < ng), max_nodes=64)
    a = _sorted_boxes(np.asarray(lo1)[np.asarray(v1)],
                      np.asarray(hi1)[np.asarray(v1)])
    b = _sorted_boxes(np.asarray(lo2)[np.asarray(v2)],
                      np.asarray(hi2)[np.asarray(v2)])
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_host_split_tables_match_in_graph(seed=5):
    """Passing split_tables' host (sort_idx, run_end) must not change the
    boxes vs the in-graph derivation."""
    xp, xn, flo, fhi = _rand_case(seed)
    si, re = split_tables(np.concatenate([xp, xn]))
    lo1, hi1, v1 = fit_dbranch_jax(
        jnp.asarray(xp), jnp.asarray(xn), jnp.asarray(flo),
        jnp.asarray(fhi), max_nodes=64)
    lo2, hi2, v2 = fit_dbranch_jax(
        jnp.asarray(xp), jnp.asarray(xn), jnp.asarray(flo),
        jnp.asarray(fhi), None, None, jnp.asarray(si), jnp.asarray(re),
        max_nodes=64)
    a = _sorted_boxes(np.asarray(lo1)[np.asarray(v1)],
                      np.asarray(hi1)[np.asarray(v1)])
    b = _sorted_boxes(np.asarray(lo2)[np.asarray(v2)],
                      np.asarray(hi2)[np.asarray(v2)])
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_every_positive_sits_in_an_emitted_box():
    """The invariant device selection rests on: false negatives are
    always zero (every positive flows to an emitted leaf), so scoring
    unexpanded boxes equals the oracle's expanded-box scoring."""
    for seed in range(8):
        xp, xn, flo, fhi = _rand_case(seed)
        d = xp.shape[1]
        bs = fit_dbranch(xp, xn, np.arange(d), max_depth=4,
                         feature_range=(flo, fhi))
        assert (bs.contains(xp) > 0).all()
        bs_raw = fit_dbranch(xp, xn, np.arange(d), max_depth=4,
                             expand=False, feature_range=(flo, fhi))
        assert (bs_raw.contains(xp) > 0).all()


# ----------------------------------------------------------------------
# engine-level parity: the production jax path vs the numpy oracle path
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def engines(catalog):
    feats, labels = catalog
    jx = SearchEngine(feats, n_subsets=12, subset_dim=5, block=128, seed=0,
                      use_jax_fit=True)
    npy = SearchEngine(feats, n_subsets=12, subset_dim=5, block=128, seed=0,
                       use_jax_fit=False)
    return jx, npy, labels


def _labels_query(labels, cls, n_pos, n_neg, seed):
    rng = np.random.default_rng(seed)
    pos = rng.choice(np.nonzero(labels == cls)[0], n_pos, replace=False)
    neg = rng.choice(np.nonzero(labels != cls)[0], n_neg, replace=False)
    return pos, neg


@pytest.mark.parametrize("model,n_models", [("dbranch", 25), ("dbens", 6)])
def test_engine_jax_fit_matches_numpy_fit(engines, model, n_models):
    jx, npy, labels = engines
    pos, neg = _labels_query(labels, 2, 14, 70, seed=3)
    r1 = jx.query(pos, neg, model=model, n_models=n_models)
    r2 = npy.query(pos, neg, model=model, n_models=n_models)
    assert r1.stats["fit_path"] == "jax"
    assert r2.stats["fit_path"] == "numpy"
    np.testing.assert_array_equal(r1.ids, r2.ids)
    np.testing.assert_array_equal(r1.scores, r2.scores)


def test_engine_fit_boxes_parity_including_subset_choice(engines):
    """_fit_boxes (jax) and the oracle pick the SAME winning subset and
    the SAME boxes — for dbranch and for every dbens member."""
    jx, npy, labels = engines
    pos, neg = _labels_query(labels, 1, 12, 60, seed=9)
    xp, xn = jx.x[pos], jx.x[neg]
    for model, n_models in (("dbranch", 25), ("dbens", 5)):
        b1 = jx._fit_boxes(model, xp, xn, max_depth=12, n_models=n_models,
                           seed=4)
        b2 = npy._fit_boxes(model, xp, xn, max_depth=12, n_models=n_models,
                            seed=4)
        assert len(b1) == len(b2)
        for a, b in zip(b1, b2):
            assert a.subset_id == b.subset_id
            a_lo, a_hi = _sorted_boxes(a.lo, a.hi)
            b_lo, b_hi = _sorted_boxes(b.lo, b.hi)
            np.testing.assert_array_equal(a_lo, b_lo)
            np.testing.assert_array_equal(a_hi, b_hi)


def test_batched_fit_equals_sequential_across_window(engines):
    """query_batch's shared batched fit answers == per-request query()
    fits, across a mixed dbranch/dbens window."""
    jx, _, labels = engines
    reqs = []
    for i in range(6):
        pos, neg = _labels_query(labels, [1, 2][i % 2], 10 + i, 50, seed=i)
        reqs.append({"pos_ids": pos, "neg_ids": neg,
                     "model": ["dbranch", "dbens"][i % 2], "n_models": 5})
    bat = jx.query_batch(reqs)
    for req, res in zip(reqs, bat):
        assert not isinstance(res, Exception), res
        seq = jx.query(req["pos_ids"], req["neg_ids"], model=req["model"],
                       n_models=req["n_models"])
        np.testing.assert_array_equal(res.ids, seq.ids)
        np.testing.assert_array_equal(res.scores, seq.scores)
    assert bat[0].stats["fit_path"] == "jax"
    assert bat[0].stats["batch_fit_s"] > 0


def test_frange_is_plumbed_into_fits(engines):
    """The engine's catalog-wide feature range must reach the trainers:
    engine fits == direct fits with feature_range=engine.frange, and the
    range genuinely matters (an out-of-sample extreme row widens it)."""
    _, npy, labels = engines
    pos, neg = _labels_query(labels, 2, 12, 60, seed=21)
    xp, xn = npy.x[pos], npy.x[neg]
    got = npy._fit_boxes("dbranch", xp, xn, max_depth=12, n_models=25,
                         seed=0)[0]
    want = fit_dbranch_best_subset(xp, xn, npy.subsets, max_depth=12,
                                   feature_range=npy.frange)
    assert got.subset_id == want.subset_id
    np.testing.assert_array_equal(got.lo, want.lo)
    np.testing.assert_array_equal(got.hi, want.hi)
    # the plumbed range must differ from the sample-derived one whenever
    # the catalog is wider than the tiny training sample
    unplumbed = fit_dbranch_best_subset(xp, xn, npy.subsets, max_depth=12)
    assert not (np.array_equal(np.asarray(got.lo), np.asarray(unplumbed.lo))
                and np.array_equal(np.asarray(got.hi),
                                   np.asarray(unplumbed.hi)))


def test_dbens_draws_shared_by_both_trainers():
    """The bootstrap/candidate draws are one code path, so the jax and
    numpy ensembles are literally the same models."""
    d1 = dbens_draws(10, 30, 8, 4, 3, seed=7)
    d2 = dbens_draws(10, 30, 8, 4, 3, seed=7)
    for (a1, b1, c1), (a2, b2, c2) in zip(d1, d2):
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(b1, b2)
        np.testing.assert_array_equal(c1, c2)


def test_dbens_numpy_uses_draws_helper(blob_data):
    x, y = blob_data
    subsets = make_subsets(x.shape[1], 8, 4, seed=0)
    ens = fit_dbens(x[y == 1][:12], x[y == 0][:40], subsets, n_models=3,
                    seed=5)
    draws = dbens_draws(12, 40, 8, 3, 5, seed=5)
    for m, (_, _, cand) in zip(ens, draws):
        assert m.subset_id in cand


# ----------------------------------------------------------------------
# property test: expansion never swallows an excluded negative
# ----------------------------------------------------------------------

def _make_label_sets(seed, p, ng, d, center, margin):
    rng = np.random.default_rng(seed)
    xp = rng.normal(center, 0.6, (p, d)).astype(np.float32)
    xn = rng.normal(0.0, 1.2, (ng, d)).astype(np.float32)
    flo = (np.minimum(xp.min(0), xn.min(0)) - margin).astype(np.float32)
    fhi = (np.maximum(xp.max(0), xn.max(0)) + margin).astype(np.float32)
    return xp, xn, flo, fhi


def _check_no_swallowed_negative(case):
    """For ANY label sets and ANY (catalog-wide) feature range, expansion
    stops halfway to the nearest excluded negative — so no training
    negative is ever inside the box union, in either trainer."""
    xp, xn, flo, fhi = case
    d = xp.shape[1]
    bs = fit_dbranch(xp, xn, np.arange(d), feature_range=(flo, fhi))
    assert (bs.contains(xn) == 0).all()
    assert (bs.contains(xp) > 0).all()
    lo, hi, valid = fit_dbranch_jax(
        jnp.asarray(xp), jnp.asarray(xn), jnp.asarray(flo),
        jnp.asarray(fhi), max_nodes=128)
    _assert_same_boxes(bs, lo, hi, valid)


@pytest.mark.parametrize("seed", range(8))
def test_expanded_boxes_never_swallow_excluded_negatives(seed):
    """Seeded spot-check of the expansion-safety property (always runs;
    the hypothesis variant below explores the space when available)."""
    rng = np.random.default_rng(1000 + seed)
    case = _make_label_sets(seed, int(rng.integers(2, 25)),
                            int(rng.integers(1, 80)),
                            int(rng.integers(2, 6)),
                            float(rng.uniform(0, 2)),
                            float(rng.uniform(0.1, 3.0)))
    _check_no_swallowed_negative(case)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                         # pragma: no cover
    st = None

if st is not None:
    settings.register_profile("fit_parity", max_examples=20, deadline=None)
    settings.load_profile("fit_parity")

    @st.composite
    def label_sets(draw):
        return _make_label_sets(
            draw(st.integers(0, 2 ** 31 - 1)), draw(st.integers(2, 25)),
            draw(st.integers(1, 80)), draw(st.integers(2, 6)),
            draw(st.floats(0.0, 2.0)), draw(st.floats(0.1, 3.0)))

    @given(label_sets())
    def test_expanded_boxes_never_swallow_excluded_negatives_hyp(case):
        _check_no_swallowed_negative(case)
