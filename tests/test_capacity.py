"""Unit tests for the shared capacity-bucketing helpers (core/capacity.py)."""
import numpy as np

from repro.core.capacity import (fit_bucket, hybrid_bucket, pow2above,
                                 pow2ceil, quantum_bucket)


def test_hybrid_bucket_pow2_small_quantum_large():
    assert hybrid_bucket(0, quantum=512) == 1
    assert hybrid_bucket(3, quantum=512) == 4
    assert hybrid_bucket(512, quantum=512) == 512
    assert hybrid_bucket(513, quantum=512) == 1024
    assert hybrid_bucket(1025, quantum=512) == 1536   # not pow2's 2048
    assert hybrid_bucket(10580, quantum=512) == 10752
    for v in range(1, 4000):
        b = hybrid_bucket(v, quantum=512)
        assert b >= v                          # never truncates
        if v > 512:
            assert b - v < 512                 # slop bounded by quantum
            assert b % 512 == 0
        else:
            assert b == pow2ceil(v)


def test_pow2ceil_is_ceiling_power_of_two():
    assert pow2ceil(0) == 1
    assert pow2ceil(1) == 1
    assert pow2ceil(2) == 2
    assert pow2ceil(3) == 4
    assert pow2ceil(4) == 4          # exact powers map to themselves
    assert pow2ceil(5) == 8
    assert pow2ceil(1023) == 1024
    assert pow2ceil(1024) == 1024


def test_pow2above_is_strictly_greater():
    assert pow2above(0) == 2         # clamps to max(v, 1) first
    assert pow2above(1) == 2
    assert pow2above(2) == 4
    assert pow2above(3) == 4
    assert pow2above(4) == 8         # exact powers bump to the next bucket
    assert pow2above(1024) == 2048


def test_pow2_flavours_differ_exactly_on_powers_of_two():
    for v in range(1, 5000):
        c, a = pow2ceil(v), pow2above(v)
        assert c >= v and (c & (c - 1)) == 0
        assert a > v and (a & (a - 1)) == 0
        if v & (v - 1) == 0:
            assert a == 2 * c
        else:
            assert a == c


def test_quantum_bucket_rounds_up_to_multiple():
    assert quantum_bucket(1, 8) == 8
    assert quantum_bucket(8, 8) == 8
    assert quantum_bucket(9, 8) == 16
    assert quantum_bucket(17, 16) == 32
    for v in range(1, 300):
        b = quantum_bucket(v, 8)
        assert b >= v and b % 8 == 0 and b - v < 8


def test_fit_bucket_applies_floor():
    assert fit_bucket(3, floor=64) == 64
    assert fit_bucket(64, floor=64) == 64
    assert fit_bucket(65, floor=64) == 128
    assert fit_bucket(200, floor=16) == 256


def test_buckets_are_idempotent():
    rng = np.random.default_rng(0)
    for v in rng.integers(1, 10**6, size=64):
        v = int(v)
        assert pow2ceil(pow2ceil(v)) == pow2ceil(v)
        assert quantum_bucket(quantum_bucket(v, 8), 8) == quantum_bucket(v, 8)


# ----------------------------------------------------------------------
# HintTable under concurrency: observers on serving threads race a
# background compaction's prune_generation and a failed-compaction
# invalidate. The copy-on-write-under-lock discipline must keep every
# operation linearizable — no lost updates within a generation, no
# resurrecting pruned generations, and no RuntimeError from mutating a
# dict another thread is iterating.
# ----------------------------------------------------------------------

def test_hint_table_concurrent_observe_prune_invalidate():
    import threading

    from repro.core.capacity import HintTable

    tab = HintTable()
    n_threads, n_ops = 4, 300
    stop = threading.Event()
    errors = []

    def observer(tid):
        try:
            for i in range(n_ops):
                gen = i % 3
                tab.observe((gen, tid % 2, 64 << (i % 4)), 10 + i)
                # readers iterate whatever consistent dict they grabbed —
                # this is the op that throws RuntimeError on a shared
                # dict mutated mid-iteration
                for k in tab:
                    tab.get(k)
                list(tab.items())
        except Exception as e:  # noqa: BLE001 — collected for the assert
            errors.append(e)

    def pruner():
        try:
            i = 0
            while not stop.is_set():
                tab.prune_generation(i % 3)
                i += 1
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    def invalidator():
        try:
            while not stop.is_set():
                tab.invalidate()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=observer, args=(t,))
               for t in range(n_threads)]
    threads += [threading.Thread(target=pruner),
                threading.Thread(target=invalidator)]
    for t in threads:
        t.start()
    for t in threads[:n_threads]:
        t.join(timeout=60)
    stop.set()
    for t in threads[n_threads:]:
        t.join(timeout=60)
    assert not errors, errors
    assert not any(t.is_alive() for t in threads)
    # whatever survived is well-formed: int values, 3-tuple keys
    for k, v in tab.items():
        assert len(k) == 3 and isinstance(v, int)


def test_hint_table_observe_never_lost_without_contention():
    """Sequential sanity for the racing test above: peak-decay semantics
    hold exactly when only one thread writes."""
    from repro.core.capacity import HintTable

    tab = HintTable()
    tab.observe((0, 0, 64), 100)
    assert tab.get((0, 0, 64)) == 100
    tab.observe((0, 0, 64), 10)            # decay: max(10, 100*3//4)
    assert tab.get((0, 0, 64)) == 75
    tab.observe((0, 0, 64), 400)           # instant rise
    assert tab.get((0, 0, 64)) == 400
    tab.prune_generation(1)
    assert (0, 0, 64) not in tab and len(tab) == 0
    tab.observe((1, 0, 64), 7)
    assert tab.invalidate() == 1 and len(tab) == 0
