"""Unit tests for the shared capacity-bucketing helpers (core/capacity.py)."""
import numpy as np

from repro.core.capacity import (fit_bucket, hybrid_bucket, pow2above,
                                 pow2ceil, quantum_bucket)


def test_hybrid_bucket_pow2_small_quantum_large():
    assert hybrid_bucket(0, quantum=512) == 1
    assert hybrid_bucket(3, quantum=512) == 4
    assert hybrid_bucket(512, quantum=512) == 512
    assert hybrid_bucket(513, quantum=512) == 1024
    assert hybrid_bucket(1025, quantum=512) == 1536   # not pow2's 2048
    assert hybrid_bucket(10580, quantum=512) == 10752
    for v in range(1, 4000):
        b = hybrid_bucket(v, quantum=512)
        assert b >= v                          # never truncates
        if v > 512:
            assert b - v < 512                 # slop bounded by quantum
            assert b % 512 == 0
        else:
            assert b == pow2ceil(v)


def test_pow2ceil_is_ceiling_power_of_two():
    assert pow2ceil(0) == 1
    assert pow2ceil(1) == 1
    assert pow2ceil(2) == 2
    assert pow2ceil(3) == 4
    assert pow2ceil(4) == 4          # exact powers map to themselves
    assert pow2ceil(5) == 8
    assert pow2ceil(1023) == 1024
    assert pow2ceil(1024) == 1024


def test_pow2above_is_strictly_greater():
    assert pow2above(0) == 2         # clamps to max(v, 1) first
    assert pow2above(1) == 2
    assert pow2above(2) == 4
    assert pow2above(3) == 4
    assert pow2above(4) == 8         # exact powers bump to the next bucket
    assert pow2above(1024) == 2048


def test_pow2_flavours_differ_exactly_on_powers_of_two():
    for v in range(1, 5000):
        c, a = pow2ceil(v), pow2above(v)
        assert c >= v and (c & (c - 1)) == 0
        assert a > v and (a & (a - 1)) == 0
        if v & (v - 1) == 0:
            assert a == 2 * c
        else:
            assert a == c


def test_quantum_bucket_rounds_up_to_multiple():
    assert quantum_bucket(1, 8) == 8
    assert quantum_bucket(8, 8) == 8
    assert quantum_bucket(9, 8) == 16
    assert quantum_bucket(17, 16) == 32
    for v in range(1, 300):
        b = quantum_bucket(v, 8)
        assert b >= v and b % 8 == 0 and b - v < 8


def test_fit_bucket_applies_floor():
    assert fit_bucket(3, floor=64) == 64
    assert fit_bucket(64, floor=64) == 64
    assert fit_bucket(65, floor=64) == 128
    assert fit_bucket(200, floor=16) == 256


def test_buckets_are_idempotent():
    rng = np.random.default_rng(0)
    for v in rng.integers(1, 10**6, size=64):
        v = int(v)
        assert pow2ceil(pow2ceil(v)) == pow2ceil(v)
        assert quantum_bucket(quantum_bucket(v, 8), 8) == quantum_bucket(v, 8)
