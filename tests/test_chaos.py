"""Chaos suite (ISSUE 7 / DESIGN.md §14): a seeded fault schedule over a
mixed query/ingest workload, checked against a fault-free oracle.

Contracts pinned here:
  * SNAPSHOT ISOLATION + RESULT PARITY: under injected transient faults
    on the device-query seams (with retries) and on compaction (with the
    old snapshot kept serving), every query that survives returns ids
    AND scores bitwise identical to the fault-free oracle run — faults
    may cost latency and retries, never correctness;
  * NO DEADLOCK: every wait in this file is bounded, the server answers
    every submitted request exactly once, and an injected HANG parked
    inside the engine is released by ``close`` instead of wedging it;
  * LEDGER CONSISTENCY: admitted = served + ingests + expired_in_queue
    + evicted + shutdown_unserved — no request is lost or double-counted
    whatever the schedule injects;
  * REPLAYABILITY: the same seed fires the same faults at the same call
    indices, run to run, across threads;
  * SURVIVAL: after every single-site fault the server still serves.
"""
import time

import numpy as np
import pytest

from repro.core.engine import SearchEngine
from repro.serve.engine import IngestRequest, QueryRequest, QueryServer
from repro.serve.faults import FaultInjector, FaultSpec
from repro.serve.policy import RetryPolicy

ENG = dict(n_subsets=4, subset_dim=4, block=64)
GET_S = 120


def _data(n=400, d=16, seed=0):
    return np.random.default_rng(seed).normal(
        0, 1, (n, d)).astype(np.float32)


def _qlabels(i):
    return list(range(5 + 2 * i)), list(range(100, 140 + 5 * i))


# one mixed schedule: queries interleaved with appends, a delete and
# compactions — every ingest is fault-free (content must match the
# oracle's for parity; ingest-site faults get their own survival test)
OPS = [("query", 0), ("query", 1), ("append", 0), ("query", 2),
       ("delete", 0), ("query", 3), ("compact", 0), ("query", 4),
       ("append", 1), ("query", 5), ("query", 6), ("compact", 1),
       ("query", 7)]


def _run_schedule(x, faults, retry):
    """Closed-loop sequential run of OPS; returns (engine, server,
    {query index -> response}). Sequential submission keeps the catalog
    content at each query identical across runs — the parity baseline."""
    eng = SearchEngine(x, **ENG, live=True, faults=faults)
    srv = QueryServer(eng, retry_policy=retry, max_results=20)
    srv.start()
    results = {}
    for rid, (op, arg) in enumerate(OPS):
        if op == "query":
            pos, neg = _qlabels(arg)
            results[arg] = srv.submit(
                QueryRequest(rid, pos, neg)).get(timeout=GET_S)
        elif op == "append":
            r = srv.submit(IngestRequest(
                rid, "append",
                features=_data(30, seed=100 + arg))).get(timeout=GET_S)
            assert r.ok                   # parity requires identical content
        elif op == "delete":
            r = srv.submit(IngestRequest(
                rid, "delete", ids=range(20, 30))).get(timeout=GET_S)
            assert r.ok
        else:
            r = srv.submit(IngestRequest(rid, "compact")).get(timeout=GET_S)
            assert r.ok
            if srv._compact_thread is not None:
                srv._compact_thread.join(timeout=60)
                assert not srv._compact_thread.is_alive()
    srv.close()
    return eng, srv, results


def _chaos_injector(seed=5):
    return FaultInjector(seed=seed, specs=[
        FaultSpec("fused_query", prob=0.12),
        FaultSpec("device_sync", prob=0.12),
        FaultSpec("device_sync", action="slow", prob=0.1, delay_s=0.01),
        FaultSpec("compact", at_calls=(1,)),
        FaultSpec("submit", action="slow", prob=0.2, delay_s=0.005)])


def test_chaos_schedule_parity_and_ledger():
    x = _data()
    retry = RetryPolicy(max_attempts=5, backoff_s=0.001)
    inj = _chaos_injector()
    _, srv, chaos = _run_schedule(x, inj, retry)
    _, osrv, oracle = _run_schedule(x, None, None)

    # the oracle run is clean end to end
    assert all(r.ok for r in oracle.values())
    assert osrv.stats["errors"] == 0

    # every seam the schedule targets was actually exercised
    assert inj.calls("fused_query") > 0
    assert inj.calls("device_sync") > 0
    assert inj.calls("compact") >= 1
    assert inj.calls("submit") == len(OPS)
    assert len(inj.fired) > 0

    # the injected compaction failure retried to success in background:
    # same final geometry as the oracle
    assert srv.stats["compaction_errors"] == 0
    assert srv.stats["compaction_retries"] >= 1
    assert srv.summary()["epoch"] == osrv.summary()["epoch"]
    assert srv.summary()["n_segments"] == osrv.summary()["n_segments"]

    # RESULT PARITY: surviving queries are bitwise the oracle's answers
    survivors = 0
    for q, resp in chaos.items():
        if not resp.ok:
            # the only acceptable loss: retries exhausted on a transient
            assert resp.error_type == "transient", resp.error
            continue
        survivors += 1
        np.testing.assert_array_equal(resp.result.ids,
                                      oracle[q].result.ids)
        np.testing.assert_array_equal(resp.result.scores,
                                      oracle[q].result.scores)
    assert survivors >= len(chaos) // 2   # retries absorb most faults

    # LEDGER: every admitted request resolved in exactly one bucket
    for s in (srv, osrv):
        st = s.stats
        assert st["admitted"] == (st["served"] + st["ingests"]
                                  + st["expired_in_queue"] + st["evicted"]
                                  + st["shutdown_unserved"])
        assert st["errors"] <= st["served"]   # errors counted within served
        assert st["shutdown_unserved"] == 0   # drain answered everything


def test_chaos_schedule_replays_bitwise():
    """Same seed -> the same faults fire at the same per-site call
    indices, independent of thread interleaving."""
    x = _data()
    retry = RetryPolicy(max_attempts=5, backoff_s=0.001)
    runs = []
    for _ in range(2):
        inj = _chaos_injector()
        _, _, results = _run_schedule(x, inj, retry)
        runs.append((sorted((r.site, r.call, r.action)
                            for r in inj.fired),
                     {q: (r.ok, r.error_type) for q, r in results.items()}))
    assert runs[0][0] == runs[1][0]       # identical fault schedule
    assert runs[0][1] == runs[1][1]       # identical outcome classes


def test_chaos_server_survives_every_fault_site():
    """One injected failure per seam, each on a fresh server: the fault
    surfaces as a typed response (never an unhandled crash, never a
    mutated catalog) and the very next operation serves cleanly."""
    x = _data(200)
    pos, neg = list(range(8)), list(range(100, 130))
    for site, op in [("append", "ingest"), ("delete", "ingest"),
                     ("fused_query", "query"), ("device_sync", "query"),
                     ("submit", "submit")]:
        inj = FaultInjector(specs=[FaultSpec(site, at_calls=(1,))])
        eng = SearchEngine(x, **ENG, live=True, faults=inj)
        srv = QueryServer(eng, faults=inj)
        epoch0 = eng._catalog.epoch
        if op == "ingest":
            kind = "append" if site == "append" else "delete"
            r = srv.handle_ingest(IngestRequest(
                0, kind, features=_data(10, seed=9), ids=[0, 1]))
            assert not r.ok and r.error_type == "transient"
            assert eng._catalog.epoch == epoch0   # atomic: no mutation
            assert srv.stats["ingest_errors"] == 1
        elif op == "query":
            r = srv.handle(QueryRequest(0, pos, neg))
            assert not r.ok and r.error_type == "transient"
        else:
            srv.start()
            r = srv.submit(QueryRequest(0, pos, neg)).get(timeout=GET_S)
            assert not r.ok and r.error_type == "transient"
            assert srv.stats["submit_faults"] == 1
        # the server still serves after the fault
        if op == "submit":
            r2 = srv.submit(QueryRequest(1, pos, neg)).get(timeout=GET_S)
        else:
            r2 = srv.handle(QueryRequest(1, pos, neg))
        assert r2.ok
        srv.close()


def test_injected_hang_released_by_close():
    """A hang parked inside the engine must not wedge shutdown:
    close(drain=False) releases the injector, the in-flight request
    resolves, and close returns promptly."""
    x = _data(200)
    inj = FaultInjector(specs=[FaultSpec("fused_query", action="hang",
                                         at_calls=(1,), delay_s=60.0)])
    eng = SearchEngine(x, **ENG, faults=inj)
    srv = QueryServer(eng)
    # warm the jit caches on a clean twin so the hang dominates timing
    SearchEngine(x, **ENG).query(list(range(8)), list(range(100, 130)),
                                 model="dbranch")
    srv.start()
    out = srv.submit(QueryRequest(0, list(range(8)),
                                  list(range(100, 130))))
    time.sleep(0.3)                       # let the loop park on the hang
    t0 = time.monotonic()
    srv.close(drain=False)
    assert time.monotonic() - t0 < 30.0   # never waits out the 60 s hang
    resp = out.get(timeout=GET_S)         # resolved, one way or the other
    assert resp.request_id == 0


# ----------------------------------------------------------------------
# seam registry: every site declared in faults.SITES must actually be
# reachable — a drive through the full lifecycle (build, mutate, query,
# checkpoint, compact, recover) fires a harmless fault at every seam.
# A seam that never fires means the registry and the wired code drifted.
# ----------------------------------------------------------------------

def test_every_registered_seam_is_reachable_and_fires():
    import tempfile

    from repro.core.segments import SegmentedCatalog
    from repro.serve.faults import SITES

    # "slow" with zero delay fires (and is recorded) without breaking
    # anything, so one schedule can cover every seam in a single run
    inj = FaultInjector(specs=[
        FaultSpec(site, action="slow", at_calls=(1,), delay_s=0.0)
        for site in SITES])
    x = _data(200)
    pos, neg = list(range(8)), list(range(100, 130))
    with tempfile.TemporaryDirectory() as d:
        # construction writes the genesis checkpoint: segment_write +
        # manifest_commit; sync="always" makes every append fsync
        eng = SearchEngine(x, **ENG, live=True, faults=inj,
                           data_dir=d, wal_sync="always")
        srv = QueryServer(eng, faults=inj)
        srv.start()
        r = srv.submit(QueryRequest(0, pos, neg)).get(timeout=GET_S)
        assert r.ok                      # submit, fused_query, device_sync
        srv.close()
        eng.append(_data(10, seed=9))    # append, wal_write/fsync/commit
        eng.delete([3, 4])               # delete
        eng.compact()                    # compact (+ durable 2PC seams)
        eng.append(_data(5, seed=10))    # a WAL tail past the horizon
        eng.close()
        # recovery reads back manifest, segments, valid overlay and the
        # WAL tail through the read seams
        cat = SegmentedCatalog.open(d, faults=inj)
        assert cat.recovery.clean
    fired_sites = {r.site for r in inj.fired}
    missing = sorted(set(SITES) - fired_sites)
    assert not missing, f"registered seams never fired: {missing}"
    for site in SITES:
        assert inj.calls(site) >= 1


def test_seam_registry_rejects_unknown_sites_both_directions():
    """The registry can't drift silently in either direction: a spec
    naming an unknown site dies at construction, and a seam calling
    check() with an unregistered name dies on its first execution."""
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("no_such_seam", at_calls=(1,))
    inj = FaultInjector()
    with pytest.raises(ValueError, match="unregistered site"):
        inj.check("no_such_seam")
