"""Per-query tracing (ISSUE 10 / DESIGN.md §17): propagation through
every serving layer, under faults, over a real socket.

Contracts pinned here:
  * an HTTP query's trace carries admission, queue, fit, >=1
    device_round, rank and cache spans, and their durations sum to
    >=90% of the measured request wall — the trace accounts for where
    the time went instead of sampling it;
  * fault-injected retries leave per-attempt evidence: a retry marker
    plus a second fit/device-round group, so a slow query's trace shows
    WHICH attempt burned the budget;
  * overflow-retry rounds (cold capacity hints) appear as extra
    device_round spans;
  * a deadline-expired request still finishes its trace with the typed
    status — rejected work is visible work;
  * trace ids are unique across concurrent submits, a caller-supplied
    ``X-Request-Id`` becomes the trace id end-to-end, and ``/metrics``
    + ``/traces`` expose the whole thing over the wire;
  * traces slower than the threshold land in the slow-query log as
    parseable JSON lines.
"""
import contextlib
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np

from repro.core.engine import SearchEngine
from repro.core.errors import deadline_after
from repro.obs import Observability
from repro.obs.trace import Trace
from repro.serve.cache import ResultCache
from repro.serve.engine import QueryRequest, QueryServer
from repro.serve.faults import FaultInjector, FaultSpec
from repro.serve.http import HttpFrontEnd
from repro.serve.policy import RetryPolicy

ENG = dict(n_subsets=4, subset_dim=4, block=64)


def _data(n=500, d=16, seed=0):
    return np.random.default_rng(seed).normal(
        0, 1, (n, d)).astype(np.float32)


def _labels():
    return list(range(10)), list(range(100, 150))


@contextlib.contextmanager
def _serving(srv):
    srv.start()
    fe = HttpFrontEnd(srv)
    host, port = fe.start()
    try:
        yield f"http://{host}:{port}"
    finally:
        fe.close()
        srv.close(drain=False)


def _post(base, path, body, headers=None, timeout=120):
    req = urllib.request.Request(
        base + path, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json", **(headers or {})},
        method="POST")
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), dict(e.headers)


def _get(base, path, timeout=30):
    with urllib.request.urlopen(base + path, timeout=timeout) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read()


def _span_names(trace_dict):
    return [s["name"] for s in trace_dict["spans"]]


# ----------------------------------------------------------------------
# Trace primitives
# ----------------------------------------------------------------------

def test_trace_span_and_mark_arithmetic():
    tr = Trace("t1")
    with tr.span("a"):
        time.sleep(0.01)
    tr.mark("q")
    time.sleep(0.01)
    tr.span_from_mark("q", "queue")
    tr.span_from_mark("q", "queue")          # consumed mark: no-op
    tr.finish("ok")
    tr.finish("late")                        # idempotent: first wins
    d = tr.to_dict()
    assert d["status"] == "ok"
    assert _span_names(d) == ["a", "queue"]
    assert all(s["dur_s"] >= 0.009 for s in d["spans"])
    assert tr.wall_s >= 0.02


# ----------------------------------------------------------------------
# the end-to-end acceptance trace (real socket)
# ----------------------------------------------------------------------

def test_http_trace_covers_90_percent_of_wall():
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=20, cache=ResultCache())
    pos, neg = _labels()
    with _serving(srv) as base:
        st, body, _ = _post(base, "/query",
                            {"pos_ids": pos, "neg_ids": neg})
        assert st == 200 and body["ok"]
        tid = body["trace_id"]
    tr = srv.obs.traces.get(tid)
    assert tr is not None and tr["status"] == "ok"
    names = _span_names(tr)
    for required in ("admission", "queue", "fit", "device_round",
                     "rank", "cache"):
        assert required in names, (required, names)
    covered = sum(s["dur_s"] for s in tr["spans"])
    assert covered >= 0.90 * tr["wall_s"], \
        f"spans cover {covered / tr['wall_s']:.1%} of wall ({names})"


def test_cache_hit_trace_has_cache_span_and_fresh_id():
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=20, cache=ResultCache())
    pos, neg = _labels()
    q = {"pos_ids": pos, "neg_ids": neg}
    with _serving(srv) as base:
        _, b1, _ = _post(base, "/query", q)
        _, b2, _ = _post(base, "/query", q)
        assert b2["cache"] == "hit"
        assert b2["trace_id"] != b1["trace_id"]
    tr = srv.obs.traces.get(b2["trace_id"])
    names = _span_names(tr)
    assert "cache" in names
    # a hit never touches the device
    assert "device_round" not in names and "fit" not in names


# ----------------------------------------------------------------------
# traces under fault injection (satellite c)
# ----------------------------------------------------------------------

def test_retry_attempts_visible_in_trace():
    inj = FaultInjector(specs=[FaultSpec("fused_query", at_calls=(1,))])
    eng = SearchEngine(_data(), **ENG, live=True, faults=inj)
    srv = QueryServer(eng, max_results=20,
                      retry_policy=RetryPolicy(max_attempts=3,
                                               backoff_s=0.001))
    srv.start()
    try:
        pos, neg = _labels()
        req = QueryRequest(1, pos, neg, "dbranch")
        resp = srv.submit(req).get(timeout=120)
        assert resp.ok
        assert srv.stats["retries"] == 1
        tr = srv.obs.traces.get(resp.info["trace_id"])
        names = _span_names(tr)
        assert names.count("retry") == 1
        # both attempts fitted and reached the device: the failed
        # attempt's spans survive next to the successful one's
        assert names.count("fit") == 2
        assert names.count("device_round") >= 2
        assert names.index("retry") > names.index("fit")
    finally:
        srv.close()


def test_overflow_retry_rounds_leave_extra_device_round_spans():
    # capacity_frac ~0 forces the cold gather capacity to 1 row per
    # subset: the first round overflows and the engine re-queues at
    # observed size — the trace must show the extra round(s)
    eng_tiny = SearchEngine(_data(), **ENG, live=True,
                            capacity_frac=1e-6)
    srv = QueryServer(eng_tiny, max_results=20)
    srv.start()
    try:
        pos, neg = _labels()
        resp = srv.submit(QueryRequest(1, pos, neg,
                                       "dbranch")).get(timeout=120)
        assert resp.ok
        tr = srv.obs.traces.get(resp.info["trace_id"])
        rounds = [s for s in tr["spans"] if s["name"] == "device_round"]
        assert len(rounds) >= 2, _span_names(tr)
    finally:
        srv.close()


def test_deadline_expired_request_still_finishes_its_trace():
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=20)
    srv.start()
    try:
        pos, neg = _labels()
        req = QueryRequest(1, pos, neg, "dbranch",
                           deadline_s=deadline_after(-1.0))
        resp = srv.submit(req).get(timeout=30)
        assert not resp.ok and resp.error_type == "deadline_exceeded"
        tr = srv.obs.traces.get(resp.info["trace_id"])
        assert tr is not None
        assert tr["status"] == "deadline_exceeded"
    finally:
        srv.close()


def test_trace_ids_unique_across_concurrent_submits():
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=20, queue_depth=256,
                      cache=ResultCache())
    srv.start()
    ids, lock = [], threading.Lock()
    pos, neg = _labels()

    def one(i):
        resp = srv.submit(QueryRequest(i, pos, neg,
                                       "dbranch")).get(timeout=120)
        with lock:
            ids.append(resp.info.get("trace_id"))

    try:
        threads = [threading.Thread(target=one, args=(i,))
                   for i in range(100)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert len(ids) == 100
        assert None not in ids
        assert len(set(ids)) == 100
    finally:
        srv.close()


# ----------------------------------------------------------------------
# wire surface: /metrics, /traces, X-Request-Id
# ----------------------------------------------------------------------

def test_metrics_endpoint_is_prometheus_text():
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=20, cache=ResultCache())
    pos, neg = _labels()
    with _serving(srv) as base:
        _post(base, "/query", {"pos_ids": pos, "neg_ids": neg})
        st, ctype, raw = _get(base, "/metrics")
        assert st == 200
        assert ctype.startswith("text/plain")
        assert "version=0.0.4" in ctype
        text = raw.decode()
    from test_obs import _assert_valid_exposition
    _assert_valid_exposition(text)
    for family in ("server_latency_seconds_bucket", "span_seconds_sum",
                   "request_seconds_count", "cache_hits_total",
                   "server_served"):
        assert family in text, family


def test_traces_endpoint_and_x_request_id_honored():
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=20)
    pos, neg = _labels()
    with _serving(srv) as base:
        st, body, hdrs = _post(base, "/query",
                               {"pos_ids": pos, "neg_ids": neg},
                               headers={"X-Request-Id": "corr-77"})
        assert st == 200
        assert body["trace_id"] == "corr-77"
        assert hdrs.get("X-Request-Id") == "corr-77"
        st2, ctype2, raw2 = _get(base, "/traces?n=10")
        assert st2 == 200 and ctype2.startswith("application/json")
        payload = json.loads(raw2)
    ids = [t["trace_id"] for t in payload["traces"]]
    assert "corr-77" in ids
    tr = [t for t in payload["traces"] if t["trace_id"] == "corr-77"][0]
    assert "device_round" in _span_names(tr)


def test_slow_query_log_lines_parse(tmp_path):
    log = tmp_path / "slow.jsonl"
    obs = Observability(slow_query_s=0.0, slow_log_path=str(log))
    eng = SearchEngine(_data(), **ENG, live=True)
    srv = QueryServer(eng, max_results=20, obs=obs)
    srv.start()
    try:
        pos, neg = _labels()
        resp = srv.submit(QueryRequest(1, pos, neg,
                                       "dbranch")).get(timeout=120)
        assert resp.ok
    finally:
        srv.close()
    lines = [json.loads(ln) for ln in
             log.read_text().strip().splitlines()]
    assert lines, "no slow-query lines written"
    entry = lines[0]
    assert entry["slow_query"] is True
    assert entry["trace_id"] == resp.info["trace_id"]
    assert entry["status"] == "ok"
    assert entry["wall_ms"] > 0
    assert "fit" in entry["spans"] and "device_round" in entry["spans"]
    assert obs.traces.slow_log(5)   # in-memory mirror carries it too
