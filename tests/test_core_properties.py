"""Hypothesis property tests on the system's core invariants.

The co-design's contract (DESIGN.md §7): for ANY data and ANY boxes,
the index path returns exactly the full-scan result set; zone pruning
never drops a matching block; DBranch boxes contain no training
negatives; k-d tree oracle agrees with both.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="dev dependency (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.boxes import BoxSet, boxes_contain
from repro.core.dbranch import fit_dbranch
from repro.core.index import build_index, morton_code, query_index
from repro.core.kdtree import build_kdtree, range_query

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def data_and_boxes(draw):
    n = draw(st.integers(16, 400))
    d = draw(st.integers(1, 6))
    b = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    # boxes around random data points (non-degenerate selectivity)
    centers = x[rng.integers(0, n, b)]
    width = np.abs(rng.normal(0.5, 0.5, (b, d))).astype(np.float32)
    lo, hi = centers - width, centers + width
    return x, lo, hi


@given(data_and_boxes())
def test_index_equals_scan(args):
    """THE paper invariant: index-accelerated range query == full scan."""
    x, lo, hi = args
    d = x.shape[1]
    dims = np.arange(d)
    idx = build_index(x, dims, block=32)
    counts, stats = query_index(idx, BoxSet(lo, hi, dims), use_pallas=True)
    want = boxes_contain(x, lo, hi)
    np.testing.assert_array_equal(counts, want)
    assert stats["blocks_touched"] <= stats["blocks_total"]


@given(data_and_boxes())
def test_zone_prune_soundness(args):
    """Pruned blocks contain no matching rows (no false negatives)."""
    x, lo, hi = args
    d = x.shape[1]
    idx = build_index(x, np.arange(d), block=32)
    from repro.kernels import ref as kref
    import jax.numpy as jnp
    mask = np.asarray(kref.zone_prune_ref(
        jnp.asarray(idx.zlo), jnp.asarray(idx.zhi),
        jnp.asarray(lo), jnp.asarray(hi)))          # [NB, B]
    rows = idx.rows.reshape(idx.n_blocks, idx.block, d)
    for bi in range(idx.n_blocks):
        for qi in range(lo.shape[0]):
            if not mask[bi, qi]:
                inside = ((rows[bi] > lo[qi]) & (rows[bi] <= hi[qi])).all(-1)
                assert not inside.any(), (bi, qi)


@given(data_and_boxes())
def test_kdtree_oracle_agreement(args):
    """Bentley k-d tree (the paper's structure) returns the same ids."""
    x, lo, hi = args
    tree = build_kdtree(x, leaf_size=16)
    counts = boxes_contain(x, lo[:1], hi[:1])
    ids_scan = np.nonzero(counts > 0)[0]
    ids_tree, touched = range_query(tree, lo[0], hi[0])
    np.testing.assert_array_equal(np.sort(ids_tree), ids_scan)
    assert touched <= len(x)


@st.composite
def labelled_data(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    n_pos = draw(st.integers(3, 30))
    n_neg = draw(st.integers(5, 80))
    d = draw(st.integers(2, 8))
    rng = np.random.default_rng(seed)
    xp = rng.normal(1.5, 0.5, (n_pos, d)).astype(np.float32)
    xn = rng.normal(0.0, 1.0, (n_neg, d)).astype(np.float32)
    return xp, xn


@given(labelled_data())
def test_dbranch_excludes_training_negatives(args):
    xp, xn = args
    d = xp.shape[1]
    bs = fit_dbranch(xp, xn, np.arange(d), max_depth=16)
    if bs.n_boxes == 0:
        return
    assert (bs.contains(xn) == 0).all(), "a training negative is inside a box"


@given(labelled_data())
def test_dbranch_covers_training_positives(args):
    """With enough depth every training positive lands in >=1 box."""
    xp, xn = args
    d = xp.shape[1]
    bs = fit_dbranch(xp, xn, np.arange(d), max_depth=64)
    # duplicated pos/neg points make a pure leaf impossible; tolerate those
    dup = (xn[None, :, :] == xp[:, None, :]).all(-1).any(1)
    covered = bs.contains(xp) > 0
    assert covered[~dup].all()


@given(labelled_data())
def test_dbranch_subset_constraint(args):
    """Boxes only constrain dims inside the declared subset."""
    xp, xn = args
    d = xp.shape[1]
    if d < 3:
        return
    dims = np.asarray([0, 2])
    bs = fit_dbranch(xp, xn, dims, max_depth=16)
    lo_full, hi_full = bs.to_full(d)
    other = np.setdiff1d(np.arange(d), dims)
    assert np.all(np.isinf(lo_full[:, other]))
    assert np.all(np.isinf(hi_full[:, other]))


@st.composite
def distinct_matrix(draw):
    """[n, d] float32 with DISTINCT values per dim (shuffled linspace):
    rank quantisation is only permutation-equivariant when no dim has
    ties — tied values take their rank from input order, which is the
    stable-sort contract, not a bug."""
    seed = draw(st.integers(0, 2**31 - 1))
    n = draw(st.integers(2, 300))
    d = draw(st.integers(1, 5))
    rng = np.random.default_rng(seed)
    x = np.stack([rng.permutation(np.linspace(-3.0, 3.0, n))
                  for _ in range(d)], axis=1).astype(np.float32)
    return x, rng.permutation(n)


@given(distinct_matrix())
def test_morton_code_permutation_equivariance(args):
    """Reordering the rows reorders the codes the SAME way — so the code
    MULTISET is permutation-invariant, and the single-argsort rank trick
    (the PR 2 fix: ranks[order] = arange instead of argsort(argsort))
    assigns ranks independent of row order. Zone-map quality therefore
    cannot depend on catalog ingestion order."""
    x, perm = args
    codes = morton_code(x)
    np.testing.assert_array_equal(morton_code(x[perm]), codes[perm])
    np.testing.assert_array_equal(np.sort(morton_code(x[perm])),
                                  np.sort(codes))


@given(distinct_matrix())
def test_morton_rank_inverse_permutation_roundtrip(args):
    """The rank table IS the inverse of the sort permutation: pushing
    codes through the permutation and back recovers them exactly, and
    the scatter-built ranks equal the double-argsort formulation the
    single-argsort fix replaced."""
    x, perm = args
    n = x.shape[0]
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    codes = morton_code(x)
    np.testing.assert_array_equal(morton_code(x[perm])[inv], codes)
    for j in range(x.shape[1]):
        order = np.argsort(x[:, j], kind="stable")
        ranks = np.empty(n, np.int64)
        ranks[order] = np.arange(n)          # the single-argsort fix
        np.testing.assert_array_equal(
            ranks, np.argsort(np.argsort(x[:, j], kind="stable"),
                              kind="stable"))


@given(st.integers(0, 2**31 - 1), st.integers(10, 300), st.integers(1, 5))
def test_morton_index_roundtrip(seed, n, d):
    """Index permutation is a bijection; counts map back to original order."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    idx = build_index(x, np.arange(d), block=16)
    valid = idx.perm >= 0
    perm = idx.perm[valid]
    assert len(np.unique(perm)) == n
    np.testing.assert_allclose(
        np.sort(idx.rows[: n], axis=0), np.sort(x, axis=0), rtol=1e-6)
