"""ZoneMapIndex + SearchEngine integration tests."""
import numpy as np
import pytest

from repro.core.boxes import BoxSet, boxes_contain
from repro.core.engine import MODELS, SearchEngine
from repro.core.index import build_index, full_scan, query_index
from repro.core.subsets import make_subsets


def test_build_index_padding_and_stats(rng):
    x = rng.normal(0, 1, (1000, 4)).astype(np.float32)
    idx = build_index(x, np.arange(4), block=64)
    assert idx.n_rows == 1000
    assert idx.rows.shape[0] % 64 == 0
    st = idx.stats()
    assert st["rows"] == 1000 and st["blocks"] == idx.n_blocks


def test_query_index_prunes(rng):
    """A tight box must touch far fewer blocks than the total."""
    x = rng.normal(0, 1, (20000, 4)).astype(np.float32)
    idx = build_index(x, np.arange(4), block=128)
    center = x[17]
    lo = (center - 0.05)[None].astype(np.float32)
    hi = (center + 0.05)[None].astype(np.float32)
    counts, stats = query_index(idx, BoxSet(lo, hi, np.arange(4)))
    np.testing.assert_array_equal(counts, boxes_contain(x, lo, hi))
    assert stats["prune_fraction"] > 0.5, stats


def test_full_scan_matches_oracle(rng):
    x = rng.normal(0, 1, (512, 8)).astype(np.float32)
    lo = x[:3] - 0.3
    hi = x[:3] + 0.3
    got = np.asarray(full_scan(x, lo, hi))
    np.testing.assert_array_equal(got, boxes_contain(x, lo, hi))


def test_subsets_are_valid():
    s = make_subsets(384, 32, 6, seed=1)
    assert s.shape == (32, 6)
    assert (s >= 0).all() and (s < 384).all()
    for row in s:
        assert len(np.unique(row)) == 6
        np.testing.assert_array_equal(row, np.sort(row))


# ----------------------------------------------------------------------
# SearchEngine end to end
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_and_labels(catalog):
    feats, labels = catalog
    eng = SearchEngine(feats, n_subsets=16, subset_dim=6, block=128, seed=0)
    return eng, labels


def _query_sets(labels, cls, n_pos=15, n_neg=60, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.choice(np.nonzero(labels == cls)[0], n_pos, replace=False)
    neg = rng.choice(np.nonzero(labels != cls)[0], n_neg, replace=False)
    return pos, neg


@pytest.mark.parametrize("model", MODELS)
def test_engine_all_models_run(engine_and_labels, model):
    eng, labels = engine_and_labels
    pos, neg = _query_sets(labels, 2)          # forest: texture-separable
    res = eng.query(pos, neg, model=model)
    assert res.model == model
    assert res.query_time_s >= 0
    assert res.ids.ndim == 1
    # scores sorted descending
    assert (np.diff(res.scores) <= 1e-9).all()


def test_engine_index_path_equals_scan_path(engine_and_labels):
    """dbranch via index == same boxes via full scan (the paper contract
    at engine level)."""
    eng, labels = engine_and_labels
    pos, neg = _query_sets(labels, 2, seed=3)
    res = eng.query(pos, neg, model="dbranch", include_training=True)
    # rebuild the same model (same plumbed feature range) and scan
    from repro.core.dbranch import fit_dbranch_best_subset
    bs = fit_dbranch_best_subset(eng.x[pos], eng.x[neg], eng.subsets,
                                 feature_range=eng.frange)
    lo, hi = bs.to_full(eng.d)
    counts = np.asarray(full_scan(eng.x, lo, hi))
    ids_scan = np.nonzero(counts > 0)[0]
    np.testing.assert_array_equal(np.sort(res.ids), np.sort(ids_scan))


def test_engine_excludes_training_by_default(engine_and_labels):
    eng, labels = engine_and_labels
    pos, neg = _query_sets(labels, 2, seed=5)
    res = eng.query(pos, neg, model="dbranch")
    assert not np.isin(res.ids, np.concatenate([pos, neg])).any()


def test_engine_stats_report_bytes_saved(engine_and_labels):
    eng, labels = engine_and_labels
    pos, neg = _query_sets(labels, 2, seed=7)
    res = eng.query(pos, neg, model="dbens", n_models=8)
    assert res.stats["path"] == "index"
    assert 0.0 <= res.stats["bytes_saved_frac"] <= 1.0
    assert res.stats["bytes_touched"] <= res.stats["scan_bytes_equiv"] * len(
        eng.indexes)


def test_engine_refine_monotone_labels(engine_and_labels):
    eng, labels = engine_and_labels
    pos, neg = _query_sets(labels, 2, seed=9)
    res1 = eng.query(pos[:8], neg[:20], model="dbranch")
    res2 = eng.refine(res1, pos[8:], neg[20:], pos[:8], neg[:20])
    assert res2.model == "dbranch"


def test_engine_quality_beats_random(engine_and_labels):
    """Search results must be enriched in the positive class vs the base
    rate (the engine actually works as a search engine)."""
    eng, labels = engine_and_labels
    cls = 2
    pos, neg = _query_sets(labels, cls, n_pos=20, n_neg=100, seed=11)
    res = eng.query(pos, neg, model="dbens", n_models=15)
    assert res.n_found > 0
    prec = (labels[res.ids] == cls).mean()
    base = (labels == cls).mean()
    assert prec > 3 * base, (prec, base)


def test_distributed_query_matches_local(rng):
    """shard_map path == local path (single-device mesh degenerate case)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.index import distributed_query
    x = rng.normal(0, 1, (2048, 4)).astype(np.float32)
    idx = build_index(x, np.arange(4), block=128)
    lo = (x[5] - 0.4)[None].astype(np.float32)
    hi = (x[5] + 0.4)[None].astype(np.float32)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    rows = idx.rows.reshape(idx.n_blocks, idx.block, -1)
    counts = np.asarray(distributed_query(
        jnp.asarray(rows), jnp.asarray(idx.zlo), jnp.asarray(idx.zhi),
        jnp.asarray(lo), jnp.asarray(hi), mesh, idx.block))
    want, _ = query_index(idx, BoxSet(lo, hi, np.arange(4)))
    # distributed returns Morton order; map back
    back = np.zeros(idx.n_rows, np.int32)
    valid = idx.perm >= 0
    back[idx.perm[valid]] = counts[valid]
    np.testing.assert_array_equal(back, want)
