"""Multi-device tests. These run in a SUBPROCESS with
``--xla_force_host_platform_device_count=8`` so the main pytest process
keeps seeing the single real device (dryrun.py owns the 512-device
override; tests must not leak device-count state)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(body: str) -> dict:
    """Run ``body`` with 8 fake CPU devices; body must print one JSON line
    prefixed RESULT:."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in stdout:\n{out.stdout[-2000:]}")


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    r = _run_in_subprocess("""
        from repro.configs import get_reduced_config
        from repro.configs.base import TrainConfig
        from repro.launch.steps import init_train_state, make_train_step
        from repro.launch import sharding as shd
        from jax.sharding import Mesh

        cfg = get_reduced_config("internlm2-1.8b", num_layers=2, d_model=64,
                                 d_ff=128, vocab_size=128, num_heads=4,
                                 num_kv_heads=2, head_dim=16)
        tc = TrainConfig(z_loss=0.0, microbatches=1, remat="none")
        mesh = jax.make_mesh((4, 2), ("data", "model"))

        state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        batch = {"inputs": jnp.zeros((8, 32), jnp.int32),
                 "targets": jnp.zeros((8, 32), jnp.int32)}
        rng = jax.random.PRNGKey(1)

        # single-device reference
        step_ref = jax.jit(make_train_step(cfg, tc, None))
        _, m_ref = step_ref(state, batch, rng)

        # sharded
        state2 = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        step = make_train_step(cfg, tc, mesh)
        psh = shd.params_shardings(state2.params, mesh)
        state2 = state2._replace(
            params=jax.device_put(state2.params, psh),
            opt=state2.opt._replace(
                m=jax.device_put(state2.opt.m, shd.params_shardings(state2.opt.m, mesh)),
                v=jax.device_put(state2.opt.v, shd.params_shardings(state2.opt.v, mesh))))
        batch_sh = jax.device_put(batch, shd.batch_shardings(batch, mesh))
        with mesh:
            _, m = jax.jit(step)(state2, batch_sh, rng)
        print("RESULT:" + json.dumps({
            "loss_sharded": float(m["loss"]),
            "loss_ref": float(m_ref["loss"])}))
    """)
    assert abs(r["loss_sharded"] - r["loss_ref"]) < 5e-2, r


@pytest.mark.slow
def test_distributed_query_sharded_equals_oracle():
    r = _run_in_subprocess("""
        from repro.core.index import build_index, distributed_query
        from repro.core.boxes import boxes_contain
        from jax.sharding import Mesh

        rng = np.random.default_rng(0)
        x = rng.normal(0, 1, (4096, 4)).astype(np.float32)
        idx = build_index(x, np.arange(4), block=64)
        lo = (x[7] - 0.3)[None].astype(np.float32)
        hi = (x[7] + 0.3)[None].astype(np.float32)
        mesh = jax.make_mesh((8,), ("data",))
        rows = idx.rows.reshape(idx.n_blocks, idx.block, -1)
        counts = np.asarray(distributed_query(
            jnp.asarray(rows), jnp.asarray(idx.zlo), jnp.asarray(idx.zhi),
            jnp.asarray(lo), jnp.asarray(hi), mesh, idx.block))
        back = np.zeros(idx.n_rows, np.int32)
        valid = idx.perm >= 0
        back[idx.perm[valid]] = counts[valid]
        want = boxes_contain(x, lo, hi)
        print("RESULT:" + json.dumps({
            "match": bool((back == want).all()),
            "found": int(want.sum())}))
    """)
    assert r["match"] and r["found"] > 0


@pytest.mark.slow
def test_elastic_reshard_preserves_state():
    r = _run_in_subprocess("""
        from repro.configs import get_reduced_config
        from repro.configs.base import TrainConfig
        from repro.launch.steps import init_train_state
        from repro.launch import sharding as shd
        from repro.train.elastic import simulate_failure_and_restart

        cfg = get_reduced_config("internlm2-1.8b", num_layers=2, d_model=64,
                                 d_ff=128, vocab_size=128)
        tc = TrainConfig()
        mesh8 = jax.make_mesh((8, 1), ("data", "model"))
        state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        ref = jax.device_get(state.params)
        sharded = jax.device_put(
            state.params, shd.params_shardings(state.params, mesh8))

        new_mesh, resharded = simulate_failure_and_restart(
            sharded,
            lambda m: shd.params_shardings(state.params, m),
            old_mesh=mesh8, surviving_devices=4, model_axis=1)
        got = jax.device_get(resharded)
        ok = all(bool(np.allclose(a, b)) for a, b in
                 zip(jax.tree.leaves(ref), jax.tree.leaves(got)))
        print("RESULT:" + json.dumps({
            "ok": ok, "new_shape": list(new_mesh.devices.shape)}))
    """)
    assert r["ok"] and r["new_shape"] == [4, 1]


@pytest.mark.slow
def test_compressed_cross_pod_mean():
    r = _run_in_subprocess("""
        from jax.sharding import Mesh
        from repro.train.compression import (Int8ErrorFeedback,
                                             compressed_cross_pod_mean)
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (16, 16)),
                              jnp.float32)}
        comp = Int8ErrorFeedback()
        ef = comp.init(g)
        out, ef = compressed_cross_pod_mean(g, ef, mesh, axis="pod")
        # replicated input -> mean across pods == dequantised input
        err = float(jnp.abs(out["w"] - g["w"]).max())
        scale = float(jnp.abs(g["w"]).max()) / 127.0
        print("RESULT:" + json.dumps({"err": err, "bound": scale}))
    """)
    assert r["err"] <= r["bound"] * 1.01 + 1e-7


@pytest.mark.slow
def test_vocab_and_expert_sharding_rules():
    r = _run_in_subprocess("""
        from repro.configs import get_reduced_config
        from repro.launch import sharding as shd
        from repro.launch.steps import init_train_state
        from repro.configs.base import TrainConfig

        cfg = get_reduced_config("qwen3-moe-235b-a22b", num_layers=2,
                                 d_model=64, d_ff=128, vocab_size=512,
                                 num_experts=4, experts_per_token=2)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        state = init_train_state(jax.random.PRNGKey(0), cfg, TrainConfig())
        sh = shd.params_shardings(state.params, mesh)
        embed_spec = str(sh["embed"].spec)
        moe_spec = str(jax.tree.leaves(
            sh["blocks"]["slot0"]["moe"])[0].spec) if "moe" in sh["blocks"]["slot0"] else "?"
        # apply them — device_put must succeed (divisibility rules hold)
        _ = jax.device_put(state.params, sh)
        print("RESULT:" + json.dumps({
            "embed_spec": embed_spec, "ok": True}))
    """)
    assert r["ok"]
    assert "model" in r["embed_spec"]
