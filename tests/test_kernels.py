"""Per-kernel correctness: shape/dtype sweeps, Pallas (interpret=True)
vs the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.box_scan import box_scan_pallas
from repro.kernels.l2dist import l2dist_pallas
from repro.kernels.zone_prune import zone_prune_pallas


def _boxes(rng, b, d, dtype=np.float32):
    lo = rng.normal(0, 1, (b, d)).astype(dtype)
    hi = lo + np.abs(rng.normal(0, 1, (b, d))).astype(dtype)
    return lo, hi


# ----------------------------------------------------------------------
# raw Pallas kernels vs oracle (aligned shapes)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,b", [(1024, 128, 4), (2048, 128, 16),
                                   (1024, 256, 1), (4096, 128, 64)])
def test_box_scan_pallas_matches_ref(n, d, b):
    rng = np.random.default_rng(n + d + b)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    lo, hi = _boxes(rng, b, d)
    got = box_scan_pallas(jnp.asarray(x), jnp.asarray(lo), jnp.asarray(hi),
                          tile_n=512, interpret=True)
    want = ref.box_scan_ref(jnp.asarray(x), jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nz,d,b", [(512, 128, 8), (1024, 128, 32),
                                    (512, 256, 2)])
def test_zone_prune_pallas_matches_ref(nz, d, b):
    rng = np.random.default_rng(nz + d + b)
    zlo, zhi = _boxes(rng, nz, d)
    blo, bhi = _boxes(rng, b, d)
    got = zone_prune_pallas(jnp.asarray(zlo), jnp.asarray(zhi),
                            jnp.asarray(blo), jnp.asarray(bhi),
                            tile_z=256, interpret=True)
    want = ref.zone_prune_ref(jnp.asarray(zlo), jnp.asarray(zhi),
                              jnp.asarray(blo), jnp.asarray(bhi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d,q", [(1024, 128, 8), (2048, 384, 4)])
def test_l2dist_pallas_matches_ref(n, d, q):
    rng = np.random.default_rng(n + d + q)
    d_pad = -(-d // 128) * 128
    x = np.zeros((n, d_pad), np.float32)
    x[:, :d] = rng.normal(0, 1, (n, d))
    qq = np.zeros((q, d_pad), np.float32)
    qq[:, :d] = rng.normal(0, 1, (q, d))
    got = l2dist_pallas(jnp.asarray(x), jnp.asarray(qq),
                        tile_n=512, interpret=True)
    want = ref.l2dist_ref(jnp.asarray(x), jnp.asarray(qq))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


# ----------------------------------------------------------------------
# public wrappers: padding hygiene (odd N, odd D, dtype sweep)
# interpret=True forces the Pallas path everywhere — with the default
# (None) the wrappers dispatch to the jnp oracle off-TPU, which would
# make these comparisons vacuous
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,b", [(100, 6, 3), (1000, 384, 25),
                                   (1023, 17, 1), (1, 6, 2), (513, 130, 7)])
def test_box_scan_wrapper_padding(n, d, b):
    rng = np.random.default_rng(n * 7 + d)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    lo, hi = _boxes(rng, b, d)
    got = ops.box_scan(jnp.asarray(x), jnp.asarray(lo), jnp.asarray(hi),
                       interpret=True)
    want = ref.box_scan_ref(jnp.asarray(x), jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_box_scan_wrapper_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (300, 12)).astype(dtype)
    lo, hi = _boxes(rng, 5, 12, np.float32)
    got = ops.box_scan(jnp.asarray(x, jnp.float32), jnp.asarray(lo),
                       jnp.asarray(hi), interpret=True)
    want = ref.box_scan_ref(jnp.asarray(x, jnp.float32), jnp.asarray(lo),
                            jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nz,d,b", [(37, 6, 3), (513, 5, 9), (1, 6, 1)])
def test_zone_prune_wrapper_padding(nz, d, b):
    rng = np.random.default_rng(nz + 1)
    zlo, zhi = _boxes(rng, nz, d)
    blo, bhi = _boxes(rng, b, d)
    got = ops.zone_prune(jnp.asarray(zlo), jnp.asarray(zhi),
                         jnp.asarray(blo), jnp.asarray(bhi), interpret=True)
    want = ref.zone_prune_ref(jnp.asarray(zlo), jnp.asarray(zhi),
                              jnp.asarray(blo), jnp.asarray(bhi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d,q,k", [(500, 6, 3, 10), (2000, 384, 2, 100)])
def test_knn_topk_matches_numpy(n, d, q, k):
    rng = np.random.default_rng(9)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    qq = rng.normal(0, 1, (q, d)).astype(np.float32)
    dists, idx = ops.knn_topk(jnp.asarray(x), jnp.asarray(qq), k)
    full = ((x[None] - qq[:, None]) ** 2).sum(-1)          # [Q, N]
    want_d = np.sort(full, axis=1)[:, :k]
    np.testing.assert_allclose(np.sort(np.asarray(dists), 1), want_d,
                               rtol=1e-4, atol=1e-3)
    # indices must be a valid top-k set (distance-equivalent)
    got_d = np.take_along_axis(full, np.asarray(idx), axis=1)
    np.testing.assert_allclose(np.sort(got_d, 1), want_d, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("b,s,hq,hkv,d,causal", [
    (2, 256, 8, 2, 32, True),
    (1, 128, 4, 4, 64, True),      # MHA
    (1, 128, 4, 1, 32, True),      # MQA
    (2, 128, 4, 2, 32, False),     # bidirectional
])
def test_flash_attention_pallas_matches_ref(b, s, hq, hkv, d, causal):
    from repro.kernels.ref import flash_attention_ref
    rng = np.random.default_rng(b + s + hq)
    q = jnp.asarray(rng.normal(0, 1, (b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, q_chunk=64, kv_chunk=64)
    g = hq // hkv
    qk = q.reshape(b, s, hkv, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b * hkv, s, g, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    want = flash_attention_ref(qk, kk, vk, causal=causal)
    want = want.reshape(b, hkv, s, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b, s, hq, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_dtypes(dtype):
    from repro.kernels.ref import flash_attention_ref
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 1, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(0, 1, (b, s, hq, d))).astype(dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d))).astype(dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d))).astype(dtype)
    got = ops.flash_attention(q, k, v, q_chunk=64, kv_chunk=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    g = hq // hkv
    qk = q.reshape(b, s, hkv, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b * hkv, s, g, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    want = flash_attention_ref(qk, kk, vk).reshape(
        b, hkv, s, g, d).transpose(0, 2, 1, 3, 4).reshape(b, s, hq, d)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_box_scan_half_open_semantics():
    """Boundary: x == lo excluded, x == hi included."""
    x = jnp.asarray([[0.0], [1.0], [0.5]])
    lo = jnp.asarray([[0.0]])
    hi = jnp.asarray([[1.0]])
    got = np.asarray(ops.box_scan(x, lo, hi))
    np.testing.assert_array_equal(got, [0, 1, 1])


def test_zone_prune_boundary_zone():
    """A zone ending exactly at box lo cannot contain a match."""
    zlo = jnp.asarray([[0.0], [2.0]])
    zhi = jnp.asarray([[1.0], [3.0]])
    blo = jnp.asarray([[1.0]])
    bhi = jnp.asarray([[2.5]])
    got = np.asarray(ops.zone_prune(zlo, zhi, blo, bhi))
    np.testing.assert_array_equal(got[:, 0], [False, True])
