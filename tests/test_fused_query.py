"""Fused device-resident query path + batched engine equivalence.

The contract (ISSUE 1 / DESIGN.md §6): query_index_fused and
SearchEngine.query_batch must return BITWISE-identical counts to the
per-query host path (query_index / query) — the fused pipeline changes
where the work runs (one jit, zero host<->device row traffic), never the
answer. Capacity bounds the gather; overflow drops survivors past the
bound in zone order, which these tests pin down explicitly.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.boxes import BoxSet, boxes_contain
from repro.core.engine import SearchEngine
from repro.core.index import (build_index, query_index, query_index_fused,
                              query_index_fused_multi)
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _random_boxes(rng, x, b, width=0.3):
    centers = x[rng.integers(0, len(x), b)]
    lo = (centers - width).astype(np.float32)
    hi = (centers + width).astype(np.float32)
    return lo, hi


# ----------------------------------------------------------------------
# box_scan_seg kernel
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,b,q", [(300, 6, 7, 3), (1024, 4, 16, 1),
                                     (513, 17, 5, 9)])
def test_box_scan_seg_matches_ref(n, d, b, q):
    rng = np.random.default_rng(n + d + b + q)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    lo, hi = _random_boxes(rng, x, b)
    seg = rng.integers(0, q, b)
    onehot = (seg[:, None] == np.arange(q)[None]).astype(np.float32)
    # interpret=True pins the Pallas kernel (default dispatch would pick
    # the oracle itself off-TPU, making the comparison vacuous)
    got = np.asarray(kops.box_scan_seg(jnp.asarray(x), jnp.asarray(lo),
                                       jnp.asarray(hi), jnp.asarray(onehot),
                                       interpret=True))
    want = np.asarray(kref.box_scan_seg_ref(jnp.asarray(x), jnp.asarray(lo),
                                            jnp.asarray(hi),
                                            jnp.asarray(onehot)))
    np.testing.assert_array_equal(got, want)
    # per-segment counts must also sum to the plain box_scan counts
    total = np.asarray(kops.box_scan(jnp.asarray(x), jnp.asarray(lo),
                                     jnp.asarray(hi)))
    np.testing.assert_array_equal(got.sum(1), total)


# ----------------------------------------------------------------------
# query_index_fused oracle
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed,n,b", [(0, 3000, 1), (1, 5000, 4),
                                      (2, 2000, 9)])
def test_fused_equals_host_path_and_oracle(seed, n, b):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 4)).astype(np.float32)
    idx = build_index(x, np.arange(4), block=128)
    lo, hi = _random_boxes(rng, x, b)
    bs = BoxSet(lo, hi, np.arange(4))
    host, st_host = query_index(idx, bs)
    fused, st_fused = query_index_fused(idx, bs)
    np.testing.assert_array_equal(fused, host)
    np.testing.assert_array_equal(fused, boxes_contain(x, lo, hi))
    assert not st_fused["overflowed"]
    assert st_fused["blocks_touched"] == st_host["blocks_touched"]


def test_fused_capacity_overflow_drops_tail_survivors():
    """capacity < survivors: exactly the first-capacity surviving blocks
    (zone order) are refined, the rest are dropped; the overflow is
    reported so callers can re-run with a larger capacity."""
    rng = np.random.default_rng(3)
    x = rng.normal(0, 1, (4000, 4)).astype(np.float32)
    idx = build_index(x, np.arange(4), block=128)
    lo, hi = _random_boxes(rng, x, 2, width=0.5)
    bs = BoxSet(lo, hi, np.arange(4))
    mask = np.asarray(kops.zone_prune(jnp.asarray(idx.zlo),
                                      jnp.asarray(idx.zhi),
                                      jnp.asarray(lo), jnp.asarray(hi)))
    hit_ids = np.nonzero(mask.any(1))[0]
    assert len(hit_ids) >= 3, "test needs several survivors"
    cap = len(hit_ids) // 2
    got, st = query_index_fused(idx, bs, capacity=cap)
    assert st["overflowed"] and st["survivors"] == len(hit_ids)
    assert st["blocks_touched"] == cap
    # reference over the first-capacity surviving blocks only
    rows3 = idx.rows.reshape(idx.n_blocks, idx.block, -1)
    counts = np.zeros(idx.rows.shape[0], np.int32)
    for bi in hit_ids[:cap]:
        c = np.asarray(kref.box_scan_ref(jnp.asarray(rows3[bi]),
                                         jnp.asarray(lo), jnp.asarray(hi)))
        counts[bi * idx.block:(bi + 1) * idx.block] = c
    want = np.zeros(idx.n_rows, np.int32)
    valid = idx.perm >= 0
    want[idx.perm[valid]] = counts[valid]
    np.testing.assert_array_equal(got, want)


def test_fused_empty_survivors():
    """A box overlapping no zone: zero counts, zero blocks touched."""
    rng = np.random.default_rng(4)
    x = rng.normal(0, 1, (2000, 4)).astype(np.float32)
    idx = build_index(x, np.arange(4), block=128)
    far = BoxSet(np.full((1, 4), 50.0, np.float32),
                 np.full((1, 4), 51.0, np.float32), np.arange(4))
    got, st = query_index_fused(idx, far)
    assert (got == 0).all()
    assert st["survivors"] == 0 and st["blocks_touched"] == 0
    assert not st["overflowed"]


def test_fused_multi_equals_per_query():
    """One fused multi call with an ownership map == per-query host path."""
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, (6000, 5)).astype(np.float32)
    idx = build_index(x, np.arange(5), block=128)
    n_queries = 4
    los, his, owner = [], [], []
    for q in range(n_queries):
        b = int(rng.integers(1, 5))
        lo, hi = _random_boxes(rng, x, b)
        los.append(lo)
        his.append(hi)
        owner.append(np.full(b, q, np.int32))
    merged = BoxSet(np.concatenate(los), np.concatenate(his), np.arange(5))
    owner = np.concatenate(owner)
    got, st = query_index_fused_multi(idx, merged, owner, n_queries)
    assert got.shape == (n_queries, idx.n_rows)
    for q in range(n_queries):
        want, _ = query_index(idx, BoxSet(los[q], his[q], np.arange(5)))
        np.testing.assert_array_equal(got[q], want)


def test_build_index_pad_rows_do_not_leak_into_zones():
    """The tail block's zone map covers REAL rows only — a query box far
    from the data must not touch the tail block (stats were previously
    inflated by the padded +inf rows leaking into zhi)."""
    rng = np.random.default_rng(6)
    x = rng.normal(0, 1, (1000, 3)).astype(np.float32)   # 1000 % 128 != 0
    idx = build_index(x, np.arange(3), block=128)
    assert np.isfinite(idx.zhi).all() and np.isfinite(idx.zlo).all()
    far = BoxSet(np.full((1, 3), 40.0, np.float32),
                 np.full((1, 3), 41.0, np.float32), np.arange(3))
    _, st = query_index(idx, far)
    assert st["blocks_touched"] == 0, st


# ----------------------------------------------------------------------
# SearchEngine.query_batch
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_and_labels(catalog):
    feats, labels = catalog
    eng = SearchEngine(feats, n_subsets=12, subset_dim=6, block=128, seed=0)
    return eng, labels


def _request(labels, cls, n_pos, n_neg, seed, **kw):
    rng = np.random.default_rng(seed)
    pos = rng.choice(np.nonzero(labels == cls)[0], n_pos, replace=False)
    neg = rng.choice(np.nonzero(labels != cls)[0], n_neg, replace=False)
    return {"pos_ids": pos, "neg_ids": neg, **kw}


def test_query_batch_equals_sequential(engine_and_labels):
    eng, labels = engine_and_labels
    reqs = [
        _request(labels, 1, 10, 40, seed=0, model="dbranch"),
        _request(labels, 2, 12, 50, seed=1, model="dbens", n_models=5),
        _request(labels, 2, 10, 40, seed=2, model="dbranch"),
        _request(labels, 3, 10, 40, seed=3, model="dbranch",
                 include_training=True),
    ]
    batch = eng.query_batch(reqs)
    for res, req in zip(batch, reqs):
        kw = {k: v for k, v in req.items()
              if k not in ("pos_ids", "neg_ids", "model")}
        seq = eng.query(req["pos_ids"], req["neg_ids"], model=req["model"],
                        **kw)
        np.testing.assert_array_equal(res.ids, seq.ids)
        np.testing.assert_array_equal(res.scores, seq.scores)
        assert res.stats["path"] == "index"
        assert res.stats["batch_size"] == len(reqs)


def test_query_batch_isolates_bad_request(engine_and_labels):
    eng, labels = engine_and_labels
    good = _request(labels, 2, 10, 40, seed=7, model="dbranch")
    bad = {"pos_ids": [1], "neg_ids": [2], "model": "not_a_model"}
    out = eng.query_batch([good, bad, good])
    assert isinstance(out[1], Exception) and "not_a_model" in str(out[1])
    np.testing.assert_array_equal(out[0].ids, out[2].ids)


def test_query_batch_mixed_models_fall_back(engine_and_labels):
    """Non-index models inside a batch are answered sequentially but the
    batch still returns aligned results."""
    eng, labels = engine_and_labels
    reqs = [_request(labels, 2, 10, 40, seed=9, model="dbranch"),
            _request(labels, 2, 10, 40, seed=9, model="dtree")]
    out = eng.query_batch(reqs)
    assert out[0].model == "dbranch" and out[1].model == "dtree"
    assert out[0].stats["path"] == "index"
    assert out[1].stats["path"] == "scan"


def test_server_batch_uses_fused_path(engine_and_labels):
    from repro.serve.engine import QueryRequest, QueryServer
    eng, labels = engine_and_labels
    srv = QueryServer(eng)
    reqs = []
    for i in range(3):
        r = _request(labels, 2, 8, 30, seed=i)
        reqs.append(QueryRequest(i, r["pos_ids"], r["neg_ids"], "dbranch"))
    resps = srv.handle_batch(reqs)
    assert all(r.ok for r in resps)
    assert srv.stats["batched_queries"] == 3
    assert srv.stats["served"] == 3
    # same answers as the sequential front door
    solo = srv.handle(QueryRequest(9, reqs[0].pos_ids, reqs[0].neg_ids))
    np.testing.assert_array_equal(resps[0].result.ids, solo.result.ids)
