"""Substrate tests: optimizer, checkpoint, compression, data, trainer."""
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, TokenSource
from repro.data.synthetic import (CLASSES, PatchDatasetConfig,
                                  generate_patches, handcrafted_features)
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (Int8ErrorFeedback, compression_ratio)
from repro.train.optimizer import (AdamW, clip_by_global_norm,
                                   cosine_schedule, global_norm)


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------

def test_adamw_single_step_closed_form():
    sched = lambda step: 0.1
    opt = AdamW(sched, beta1=0.9, beta2=0.99, weight_decay=0.0)
    p = {"w": jnp.asarray([[1.0, 2.0]])}
    g = {"w": jnp.asarray([[0.5, -0.5]])}
    st = opt.init(p)
    newp, _ = opt.update(g, st, p)
    # bias-corrected first step: mhat = g, vhat = g^2 -> delta = sign(g)
    want = np.asarray([[1.0, 2.0]]) - 0.1 * np.sign([[0.5, -0.5]])
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-4)


def test_adamw_weight_decay_skips_vectors():
    opt = AdamW(lambda s: 0.1, weight_decay=0.5)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    newp, _ = opt.update(g, opt.init(p), p)
    assert float(newp["w"][0, 0]) < 1.0      # decayed
    np.testing.assert_allclose(np.asarray(newp["b"]), 1.0)  # not decayed


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < float(s(50)) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), 20.0, rtol=1e-5)


def test_adafactor_reduces_loss():
    from repro.train.optimizer import Adafactor
    opt = Adafactor(lambda s: 0.1)
    w = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 8)),
                          jnp.float32)}
    st = opt.init(w)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    l0 = float(loss(w))
    for _ in range(20):
        g = jax.grad(loss)(w)
        w, st = opt.update(g, st, w)
    assert float(loss(w)) < l0 * 0.5


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------

def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"params": {"w": r.normal(0, 1, (4, 4)).astype(np.float32),
                       "b": r.normal(0, 1, (4,)).astype(np.float32)},
            "step": np.asarray(7, np.int32)}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path)
    t = _tree()
    cm.save(7, t)
    got = cm.restore(jax.tree.map(np.zeros_like, t))
    jax.tree.map(np.testing.assert_array_equal, got, t)


def test_checkpoint_latest_and_gc(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    assert cm.list_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_async_and_wait(tmp_path):
    cm = CheckpointManager(tmp_path)
    t = _tree()
    cm.save_async(5, t)
    cm.wait()
    assert cm.latest_step() == 5
    got = cm.restore(jax.tree.map(np.zeros_like, t), step=5)
    jax.tree.map(np.testing.assert_array_equal, got, t)


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, _tree())
    # simulate a crashed mid-write directory (no manifest)
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "params__w.npy").write_bytes(b"garbage")
    assert cm.list_steps() == [1]
    assert cm.latest_step() == 1


def test_checkpoint_restores_into_jax_state(tmp_path):
    from repro.configs import get_reduced_config
    from repro.configs.base import TrainConfig
    from repro.launch.steps import init_train_state
    cfg = get_reduced_config("internlm2-1.8b")
    tc = TrainConfig()
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    cm = CheckpointManager(tmp_path)
    cm.save(0, jax.device_get(state))
    restored = cm.restore(state)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), jax.device_get(state), restored)


# ----------------------------------------------------------------------
# compression
# ----------------------------------------------------------------------

def test_int8_quantization_error_bounded():
    comp = Int8ErrorFeedback()
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 64)),
                          jnp.float32)}
    ef = comp.init(g)
    q, ef = comp.compress(g, ef)
    back = comp.decompress(q)
    err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"])).max()
    scale = float(np.abs(np.asarray(g["w"])).max()) / 127.0
    assert err <= scale * 0.5 + 1e-7


def test_error_feedback_is_unbiased_over_steps():
    """Sum of dequantised grads + final EF == sum of raw grads (exact
    telescoping identity of error feedback)."""
    comp = Int8ErrorFeedback()
    rng = np.random.default_rng(1)
    g0 = {"w": jnp.zeros((32,), jnp.float32)}
    ef = comp.init(g0)
    total_raw = np.zeros(32)
    total_deq = np.zeros(32)
    for step in range(20):
        g = {"w": jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)}
        q, ef = comp.compress(g, ef)
        total_raw += np.asarray(g["w"])
        total_deq += np.asarray(comp.decompress(q)["w"])
    resid = np.asarray(ef["w"])
    np.testing.assert_allclose(total_deq + resid, total_raw, rtol=1e-4,
                               atol=1e-4)


def test_compression_ratio():
    g = {"w": jnp.zeros((1000,), jnp.float32)}
    r = compression_ratio(g)
    assert 0.24 < r < 0.27


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------

def test_token_source_deterministic():
    dc = DataConfig(seq_len=32, global_batch=4, vocab_size=64, seed=5)
    a = TokenSource(dc).batch(3)
    b = TokenSource(dc).batch(3)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    np.testing.assert_array_equal(a["targets"], b["targets"])


def test_token_source_hosts_disjoint():
    dc0 = DataConfig(seq_len=16, global_batch=8, num_hosts=2, host_id=0)
    dc1 = DataConfig(seq_len=16, global_batch=8, num_hosts=2, host_id=1)
    b0 = TokenSource(dc0).batch(0)
    b1 = TokenSource(dc1).batch(0)
    assert b0["inputs"].shape == (4, 16)
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_token_targets_shifted():
    dc = DataConfig(seq_len=16, global_batch=2)
    b = TokenSource(dc).batch(0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


def test_prefetcher_order_and_resume():
    dc = DataConfig(seq_len=8, global_batch=2, seed=1)
    src = TokenSource(dc)
    pf = Prefetcher(src, start_step=0)
    got = [next(pf) for _ in range(4)]
    pf.close()
    pf2 = Prefetcher(src, start_step=2)    # resume at step 2
    resumed = next(pf2)
    pf2.close()
    np.testing.assert_array_equal(resumed["inputs"], got[2]["inputs"])


def test_patch_generator_labels_and_shapes():
    data = generate_patches(PatchDatasetConfig(n_patches=64, patch_size=32,
                                               seed=0))
    assert data["images"].shape == (64, 32, 32, 3)
    assert data["images"].min() >= 0 and data["images"].max() <= 1
    assert set(np.unique(data["labels"])).issubset(set(range(len(CLASSES))))
    # determinism
    again = generate_patches(PatchDatasetConfig(n_patches=64, patch_size=32,
                                                seed=0))
    np.testing.assert_array_equal(data["images"], again["images"])


def test_handcrafted_features_separate_classes():
    data = generate_patches(PatchDatasetConfig(n_patches=400, seed=1))
    f = handcrafted_features(data["images"])
    y = data["labels"]
    # water (3) vs background (0): means must differ significantly
    if (y == 3).sum() > 3:
        d = np.linalg.norm(f[y == 3].mean(0) - f[y == 0].mean(0))
        assert d > 1.0, d


# ----------------------------------------------------------------------
# trainer end-to-end (reduced arch, CPU)
# ----------------------------------------------------------------------

def test_trainer_runs_and_checkpoints(tmp_path):
    from repro.configs import get_reduced_config
    from repro.configs.base import TrainConfig
    from repro.train.trainer import Trainer
    cfg = get_reduced_config("internlm2-1.8b", num_layers=2, d_model=64,
                             d_ff=128, vocab_size=128)
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=20,
                     z_loss=0.0)
    dc = DataConfig(seq_len=32, global_batch=4, vocab_size=128)
    tr = Trainer(cfg, tc, dc, checkpoint_dir=tmp_path, checkpoint_every=5,
                 step_deadline_s=600)
    state, report = tr.run(10, log_every=0)
    assert report.steps_run == 10
    assert np.isfinite(report.final_loss)
    tr.ckpt.wait()
    assert tr.ckpt.latest_step() == 10

    # resume: next run starts from step 10 and reproduces the data order
    tr2 = Trainer(cfg, tc, dc, checkpoint_dir=tmp_path, checkpoint_every=5,
                  step_deadline_s=600)
    state2, report2 = tr2.run(3, log_every=0)
    assert report2.resumed_from == 10
    assert report2.steps_run == 3


def test_trainer_loss_decreases():
    from repro.configs import get_reduced_config
    from repro.configs.base import TrainConfig
    from repro.train.trainer import Trainer
    cfg = get_reduced_config("internlm2-1.8b", num_layers=2, d_model=64,
                             d_ff=128, vocab_size=64)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                     z_loss=0.0)
    dc = DataConfig(seq_len=64, global_batch=8, vocab_size=64)
    tr = Trainer(cfg, tc, dc, step_deadline_s=600)
    _, report = tr.run(60, log_every=0)
    first = np.mean(report.losses[:5])
    last = np.mean(report.losses[-5:])
    assert last < first - 0.3, (first, last)
