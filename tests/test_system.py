"""End-to-end system test: the full paper workflow on synthetic data.

offline: generate catalog -> extract features -> build subsets+indexes
online : label a handful of patches -> fit DBranch -> range queries ->
         ranked results; compare quality + bytes against the scan models.
"""
import numpy as np
import pytest

from repro.core.engine import SearchEngine
from repro.data.synthetic import (CLASS_IDS, PatchDatasetConfig,
                                  generate_patches, handcrafted_features)


@pytest.fixture(scope="module")
def workflow():
    data = generate_patches(PatchDatasetConfig(n_patches=3000, seed=13))
    feats = handcrafted_features(data["images"])
    engine = SearchEngine(feats, n_subsets=24, subset_dim=6, block=128,
                          seed=13)
    return engine, data["labels"]


def _labels_for(labels, cls, n_pos, n_neg, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.choice(np.nonzero(labels == cls)[0], n_pos, replace=False)
    neg = rng.choice(np.nonzero(labels != cls)[0], n_neg, replace=False)
    return pos, neg


def test_search_by_classification_workflow(workflow):
    engine, labels = workflow
    cls = CLASS_IDS["forest"]
    pos, neg = _labels_for(labels, cls, 20, 120, seed=1)

    res = engine.query(pos, neg, model="dbens", n_models=15)
    assert res.n_found > 0
    precision = (labels[res.ids] == cls).mean()
    base_rate = (labels == cls).mean()
    assert precision > 4 * base_rate, (precision, base_rate)
    assert res.stats["path"] == "index"


def test_index_models_agree_with_scan_models_on_quality(workflow):
    """Paper claim: DBranch quality ~ decision-tree quality. We assert
    the F1 gap on the synthetic task stays bounded."""
    engine, labels = workflow
    cls = CLASS_IDS["forest"]
    pos, neg = _labels_for(labels, cls, 25, 150, seed=2)
    truth = labels == cls

    def f1(res):
        pred = np.zeros(len(labels), bool)
        pred[res.ids] = True
        tp = (pred & truth).sum()
        if tp == 0:
            return 0.0
        p = tp / pred.sum()
        r = tp / truth.sum()
        return 2 * p * r / (p + r)

    f1_db = f1(engine.query(pos, neg, model="dbens", n_models=15))
    f1_rf = f1(engine.query(pos, neg, model="rforest", n_models=15))
    assert f1_db > 0.2, f1_db
    assert f1_db > f1_rf - 0.35, (f1_db, f1_rf)


def test_refinement_loop(workflow):
    """Paper §5: refining with more labels must not crash and should keep
    or improve precision."""
    engine, labels = workflow
    cls = CLASS_IDS["water"]
    pos, neg = _labels_for(labels, cls, 10, 60, seed=3)
    res1 = engine.query(pos, neg, model="dbranch")
    pos2, neg2 = _labels_for(labels, cls, 25, 150, seed=4)
    res2 = engine.refine(res1, pos2, neg2, pos, neg)
    assert res2.n_found >= 0
    if res1.n_found and res2.n_found:
        p1 = (labels[res1.ids] == cls).mean()
        p2 = (labels[res2.ids] == cls).mean()
        assert p2 > p1 - 0.25


def test_query_time_index_beats_scan(workflow):
    """The headline: index-aware query touches a small fraction of the
    catalog bytes (the latency proxy that holds at any scale)."""
    engine, labels = workflow
    cls = CLASS_IDS["forest"]
    pos, neg = _labels_for(labels, cls, 20, 120, seed=5)
    res_idx = engine.query(pos, neg, model="dbranch")
    res_scan = engine.query(pos, neg, model="dtree")
    frac = res_idx.stats["bytes_touched"] / res_scan.stats["bytes_touched"]
    assert frac < 0.6, f"index touched {frac:.1%} of scan bytes"
