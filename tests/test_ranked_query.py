"""Device-resident scoring + ranking (ISSUE 2 / DESIGN.md §9).

Contracts pinned here:
  * kops.rank_topk reproduces the host ranking oracle SearchEngine._rank
    EXACTLY — descending score, ascending id on ties — on both the
    id-composed top_k path and the two-key sort fallback;
  * the ranked engine path (max_results=k) returns the exact k-prefix of
    the host oracle, ties included, for sequential and batched queries;
  * overflow handling is deferred to ONE batched sync and retries ONLY
    the overflowed subsets, with results bitwise-identical to the
    query_index host path;
  * batch-wide aggregates are namespaced batch_*; per-request stats carry
    that request's own n_boxes.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.boxes import BoxSet
from repro.core.engine import SearchEngine
from repro.core.index import build_index, morton_code, query_index
from repro.kernels import ops as kops


def _host_rank(counts, train_ids):
    """The oracle, standalone: stable argsort of -counts over found rows."""
    found = np.nonzero(counts > 0)[0]
    found = found[~np.isin(found, train_ids)]
    order = np.argsort(-counts[found], kind="stable")
    return found[order], counts[found][order]


# ----------------------------------------------------------------------
# kops.rank_topk against the host oracle
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed,nq,n,smax", [(0, 1, 500, 4), (1, 3, 1000, 2),
                                            (2, 5, 257, 9)])
@pytest.mark.parametrize("method", ["topk", "sort", "threshold"])
def test_rank_topk_matches_host_oracle(seed, nq, n, smax, method):
    """Low smax forces heavy score ties — the id tie-break must match the
    host stable sort on ALL THREE implementations."""
    rng = np.random.default_rng(seed)
    scores = rng.integers(0, smax + 1, (nq, n)).astype(np.int32)
    tids = np.full((nq, 8), n, np.int32)
    for q in range(nq):
        tids[q, :4] = rng.choice(n, 4, replace=False)
    ids_k, scores_k, n_valid = kops.rank_topk(
        jnp.asarray(scores), jnp.asarray(tids), k=n, score_bound=smax,
        method=method)
    ids_k, scores_k = np.asarray(ids_k), np.asarray(scores_k)
    n_valid = np.asarray(n_valid)
    for q in range(nq):
        want_ids, want_scores = _host_rank(scores[q], tids[q, :4])
        nv = int(n_valid[q])
        assert nv == len(want_ids)
        np.testing.assert_array_equal(ids_k[q, :nv], want_ids)
        np.testing.assert_array_equal(scores_k[q, :nv], want_scores)
        # past the valid prefix: sentinel ids
        assert (ids_k[q, nv:] == -1).all()


def test_rank_topk_truncates_exact_prefix():
    """k < n_found must return exactly the first k of the full host
    ranking — including ties straddling the k boundary (id-ascending)."""
    rng = np.random.default_rng(7)
    n = 400
    scores = rng.integers(0, 3, (1, n)).astype(np.int32)   # massive ties
    empty = np.full((1, 1), n, np.int32)
    want_ids, _ = _host_rank(scores[0], np.empty(0, np.int64))
    for method in ("topk", "sort", "threshold"):
        for k in (1, 7, 50):
            ids_k, _, n_valid = kops.rank_topk(
                jnp.asarray(scores), jnp.asarray(empty), k=k, score_bound=2,
                method=method)
            np.testing.assert_array_equal(
                np.asarray(ids_k)[0, :min(int(n_valid[0]), k)],
                want_ids[:k])


def test_rank_topk_methods_agree():
    rng = np.random.default_rng(11)
    scores = rng.integers(0, 6, (4, 777)).astype(np.int32)
    tids = np.full((4, 1), 777, np.int32)
    a = kops.rank_topk(jnp.asarray(scores), jnp.asarray(tids), k=64,
                       score_bound=5, method="topk")
    for method in ("sort", "threshold"):
        b = kops.rank_topk(jnp.asarray(scores), jnp.asarray(tids), k=64,
                           score_bound=5, method=method)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------------
# accumulate_scores
# ----------------------------------------------------------------------

def test_accumulate_scores_matches_host_scatter():
    """Device scatter-add over gathered blocks == query_index counts in
    original row order, summed across subsets."""
    rng = np.random.default_rng(3)
    x = rng.normal(0, 1, (1000, 4)).astype(np.float32)   # padded tail block
    idx = build_index(x, np.arange(4), block=128)
    centers = x[rng.integers(0, len(x), 3)]
    bs = BoxSet((centers - 0.4).astype(np.float32),
                (centers + 0.4).astype(np.float32), np.arange(4))
    want, _ = query_index(idx, bs)

    rows3, zlo, zhi = idx.device_arrays()
    onehot = jnp.ones((3, 1), jnp.float32)
    counts, cand, n_hit = kops.fused_query(
        rows3, zlo, zhi, jnp.asarray(bs.lo), jnp.asarray(bs.hi), onehot,
        capacity=idx.n_blocks)
    scores = jnp.zeros((idx.n_rows, 1), jnp.int32)
    scores = kops.accumulate_scores(scores, counts, cand,
                                    idx.device_inv_perm(), nb=idx.n_blocks)
    # accumulation is additive: a second pass doubles every count
    twice = kops.accumulate_scores(scores, counts, cand,
                                   idx.device_inv_perm(), nb=idx.n_blocks)
    np.testing.assert_array_equal(np.asarray(scores)[:, 0], want)
    np.testing.assert_array_equal(np.asarray(twice)[:, 0], 2 * want)


# ----------------------------------------------------------------------
# engine: ranked path == host oracle; overflow retry; tie-breaks
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_and_labels(catalog):
    feats, labels = catalog
    eng = SearchEngine(feats, n_subsets=10, subset_dim=6, block=128, seed=0)
    return eng, labels


def _query_sets(labels, cls, n_pos=12, n_neg=50, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.choice(np.nonzero(labels == cls)[0], n_pos, replace=False)
    neg = rng.choice(np.nonzero(labels != cls)[0], n_neg, replace=False)
    return pos, neg


@pytest.mark.parametrize("model,seed", [("dbranch", 0), ("dbranch", 1),
                                        ("dbens", 2)])
def test_engine_ranked_equals_host_oracle(engine_and_labels, model, seed):
    """max_results >= n_found: device ranking returns the IDENTICAL id and
    score sequence as the host _rank oracle (ties included)."""
    eng, labels = engine_and_labels
    pos, neg = _query_sets(labels, 2, seed=seed)
    kw = dict(n_models=5) if model == "dbens" else {}
    host = eng.query(pos, neg, model=model, **kw)
    dev = eng.query(pos, neg, model=model, max_results=eng.n, **kw)
    np.testing.assert_array_equal(dev.ids, host.ids)
    np.testing.assert_array_equal(dev.scores, host.scores)
    # and the truncated variant is the exact prefix
    k = max(1, host.n_found // 2)
    trunc = eng.query(pos, neg, model=model, max_results=k, **kw)
    np.testing.assert_array_equal(trunc.ids, host.ids[:k])
    np.testing.assert_array_equal(trunc.scores, host.scores[:k])


def test_engine_ranked_tie_break_with_duplicate_rows():
    """Duplicate feature rows => identical scores for whole row groups;
    device top-k order must still equal the host stable sort exactly."""
    rng = np.random.default_rng(5)
    base = rng.normal(0, 1, (40, 12)).astype(np.float32)
    x = np.tile(base, (25, 1))                      # 1000 rows, 25x ties
    eng = SearchEngine(x, n_subsets=6, subset_dim=4, block=64, seed=1)
    pos, neg = list(range(5)), list(range(600, 640))
    host = eng.query(pos, neg, model="dbranch")
    dev = eng.query(pos, neg, model="dbranch", max_results=eng.n)
    assert host.n_found > 0
    np.testing.assert_array_equal(dev.ids, host.ids)
    np.testing.assert_array_equal(dev.scores, host.scores)


def test_engine_overflow_retry_is_exact_and_minimal(catalog):
    """capacity_frac small enough to overflow: the deferred-sync path must
    (a) return counts/ids bitwise-identical to the query_index host path,
    (b) retry ONLY the subsets whose survivors exceeded their capacity,
    (c) resolve in one extra round (one extra host sync)."""
    feats, labels = catalog
    eng = SearchEngine(feats, n_subsets=8, subset_dim=6, block=128, seed=0,
                       capacity_frac=0.01)          # cap = 1 block
    pos, neg = _query_sets(labels, 2, seed=4)
    # snapshot the cold-start capacities BEFORE querying: the deferred
    # sync feeds survivor hints back into _initial_capacity afterwards
    cold_caps = {ix.subset_id: eng._initial_capacity(ix)
                 for ix in eng.indexes}
    res = eng.query(pos, neg, model="dbens", n_models=6)

    # oracle: same boxes through the host query_index path
    boxsets = eng._fit_boxes("dbens", eng.x[pos], eng.x[neg],
                             max_depth=12, n_models=6, seed=0)
    jobs, _ = eng._make_jobs([(bs, 0) for bs in boxsets], 1)
    counts = np.zeros(eng.n, np.int64)
    expected_overflows = 0
    for sid, merged, _ in jobs:
        c, st = query_index(eng.indexes[sid], merged)
        counts += c
        if st["blocks_touched"] > cold_caps[sid]:
            expected_overflows += 1
    assert expected_overflows > 0, "test needs at least one overflow"
    want_ids, want_scores = _host_rank(
        counts, np.concatenate([pos, neg]))
    np.testing.assert_array_equal(res.ids, want_ids)
    np.testing.assert_array_equal(res.scores, want_scores)
    # only the overflowed subsets were re-run, in one extra round
    assert res.stats["retried_subsets"] == expected_overflows
    assert res.stats["n_host_syncs"] == 2

    # no overflow => exactly ONE deferred sync for the whole query
    eng_big = SearchEngine(feats, n_subsets=8, subset_dim=6, block=128,
                           seed=0, capacity_frac=1.0)
    res_big = eng_big.query(pos, neg, model="dbens", n_models=6)
    assert res_big.stats["n_host_syncs"] == 1
    assert res_big.stats["retried_subsets"] == 0
    np.testing.assert_array_equal(res_big.ids, want_ids)


def test_query_batch_stats_are_batch_namespaced(engine_and_labels):
    eng, labels = engine_and_labels
    reqs = []
    for i in range(3):
        pos, neg = _query_sets(labels, 2, seed=20 + i)
        reqs.append({"pos_ids": pos, "neg_ids": neg, "model": "dbranch"})
    outs = eng.query_batch(reqs)
    for o in outs:
        # batch-wide aggregates are namespaced; none leak un-prefixed
        for key in ("bytes_touched", "blocks_touched", "bytes_saved_frac",
                    "n_range_queries", "host_bytes_transferred"):
            assert key not in o.stats
            assert f"batch_{key}" in o.stats
        assert o.stats["path"] == "index"
        assert o.stats["batch_size"] == 3
        assert o.stats["n_boxes"] >= 1          # per-request figure
    # batch aggregates identical across the batch (shared device phase)
    assert outs[0].stats["batch_bytes_touched"] == \
        outs[1].stats["batch_bytes_touched"]


def test_query_batch_ranked_matches_sequential_ranked(engine_and_labels):
    eng, labels = engine_and_labels
    reqs = []
    for i in range(3):
        pos, neg = _query_sets(labels, 2, seed=30 + i)
        reqs.append({"pos_ids": pos, "neg_ids": neg, "model": "dbranch",
                     "max_results": 25})
    outs = eng.query_batch(reqs)
    for o, r in zip(outs, reqs):
        seq = eng.query(r["pos_ids"], r["neg_ids"], model="dbranch",
                        max_results=25)
        np.testing.assert_array_equal(o.ids, seq.ids)
        np.testing.assert_array_equal(o.scores, seq.scores)
        assert o.n_found <= 25
    # ranked batch moves O(k), not O(N): well under one score vector
    assert outs[0].stats["batch_host_bytes_transferred"] < 4 * eng.n


def test_server_plumbs_max_results(engine_and_labels):
    from repro.serve.engine import QueryRequest, QueryServer
    eng, labels = engine_and_labels
    srv = QueryServer(eng, max_results=10)
    pos, neg = _query_sets(labels, 2, seed=40)
    resp = srv.handle(QueryRequest(0, pos, neg, "dbranch"))
    assert resp.ok and resp.result.n_found <= 10
    full = eng.query(pos, neg, model="dbranch")
    np.testing.assert_array_equal(resp.result.ids, full.ids[:10])
    # per-request kwargs override the serving default
    resp3 = srv.handle(QueryRequest(1, pos, neg, "dbranch",
                                    kwargs={"max_results": 3}))
    assert resp3.result.n_found <= 3
    assert srv.stats["host_bytes"] > 0
    # batched window: ranked end to end, host_bytes counted once
    before = srv.stats["host_bytes"]
    reqs = [QueryRequest(i, *_query_sets(labels, 2, seed=50 + i), "dbranch")
            for i in range(3)]
    resps = srv.handle_batch(reqs)
    assert all(r.ok and r.result.n_found <= 10 for r in resps)
    batch_bytes = resps[0].result.stats["batch_host_bytes_transferred"]
    assert srv.stats["host_bytes"] == before + batch_bytes


# ----------------------------------------------------------------------
# morton_code: single argsort + inverse == the old double argsort
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed,n,d", [(0, 1000, 4), (1, 257, 7)])
def test_morton_single_argsort_matches_double(seed, n, d):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    x[rng.integers(0, n, n // 4)] = x[0]            # ties exercise stability

    def morton_double_argsort(x, nbits=8):
        from repro.core.index import _part_bits
        n, d = x.shape
        nbits = min(nbits, 64 // max(d, 1))
        code = np.zeros(n, np.uint64)
        levels = 1 << nbits
        for j in range(d):
            ranks = np.argsort(np.argsort(x[:, j], kind="stable"),
                               kind="stable")
            q = (ranks * levels // max(n, 1)).astype(np.uint64)
            code |= _part_bits(q, d, nbits) << j
        return code

    np.testing.assert_array_equal(morton_code(x),
                                  morton_double_argsort(x))
