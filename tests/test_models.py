"""Model-layer tests: attention variants, SSM, RG-LRU, MoE vs references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models.common import ParallelCtx
from repro.models.moe import init_moe, moe_mlp, moe_mlp_reference
from repro.models.rglru import (init_lru_state, init_rglru, rglru_decode_step,
                                rglru_forward)
from repro.models.ssm import (init_ssd, init_ssm_state, ssd_decode_step,
                              ssd_forward)

CTX = ParallelCtx()


def _qkv(rng, b, s, hq, hkv, d):
    q = rng.normal(0, 1, (b, s, hq, d)).astype(np.float32)
    k = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
    v = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (4, 1)])
def test_flash_matches_full(hq, hkv):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 256, hq, hkv, 16)
    full = A.full_attention(q, k, v, causal=True)
    flash = A.flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_flash_kvscan_matches_full():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 1, 128, 6, 2, 8)
    full = A.full_attention(q, k, v, causal=True)
    got = A.flash_attention_kvscan(q, k, v, causal=True, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 64])
def test_local_matches_full_with_window_mask(window):
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 1, 256, 4, 1, 8)
    want = A.full_attention(q, k, v, causal=True, window=window)
    got = A.local_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_full_last_position():
    rng = np.random.default_rng(3)
    b, s, hq, hkv, d = 2, 64, 4, 2, 8
    q, k, v = _qkv(rng, b, s, hq, hkv, d)
    full = A.full_attention(q, k, v, causal=True)
    got = A.decode_attention(q[:, -1:], k, v, jnp.asarray(s - 1))
    np.testing.assert_allclose(np.asarray(got)[:, 0], np.asarray(full)[:, -1],
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# SSM (mamba2 / SSD)
# ----------------------------------------------------------------------

def _ssm_cfg(**kw):
    base = dict(name="t", family="ssm", num_layers=1, d_model=32,
                vocab_size=64, ssm_state=8, ssm_expand=2, ssm_head_dim=16,
                ssm_chunk=8, ssm_conv_width=4)
    base.update(kw)
    return ModelConfig(**base)


def _ssd_sequential_oracle(params, x, cfg):
    """Token-by-token recurrence using the decode step — the slow exact
    reference for the chunked scan."""
    b = x.shape[0]
    st = init_ssm_state(cfg, b, x.dtype)
    outs = []
    for t in range(x.shape[1]):
        y, st = ssd_decode_step(params, x[:, t:t + 1], cfg, CTX, st)
        outs.append(y)
    return jnp.concatenate(outs, axis=1), st


@pytest.mark.parametrize("s", [16, 24])   # 24: not a chunk multiple
def test_ssd_chunked_matches_sequential(s):
    cfg = _ssm_cfg()
    rng = jax.random.PRNGKey(0)
    params = init_ssd(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model))
    # prefill path carries state (needed by the oracle comparison)
    st0 = init_ssm_state(cfg, 2, jnp.float32)
    y_chunk, st_chunk = ssd_forward(params, x, cfg, CTX, st0)
    y_seq, st_seq = _ssd_sequential_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk.ssd), np.asarray(st_seq.ssd),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk.conv),
                               np.asarray(st_seq.conv), rtol=1e-4, atol=1e-5)


def test_ssd_decode_continues_prefill():
    cfg = _ssm_cfg()
    params = init_ssd(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, cfg.d_model))
    full, _ = ssd_forward(params, x, cfg, CTX, init_ssm_state(cfg, 1, jnp.float32))
    pre, st = ssd_forward(params, x[:, :8], cfg, CTX,
                          init_ssm_state(cfg, 1, jnp.float32))
    outs = [pre]
    for t in range(8, 12):
        y, st = ssd_decode_step(params, x[:, t:t + 1], cfg, CTX, st)
        outs.append(y)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------------
# RG-LRU
# ----------------------------------------------------------------------

def _lru_cfg():
    return ModelConfig(name="t", family="hybrid", num_layers=1, d_model=16,
                       vocab_size=64, num_heads=2, num_kv_heads=1, d_ff=32,
                       lru_width=16, attn_period=3, local_window=8)


def test_rglru_scan_matches_sequential():
    cfg = _lru_cfg()
    params = init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model))
    st0 = init_lru_state(cfg, 2, jnp.float32)
    y_scan, st_scan = rglru_forward(params, x, cfg, CTX, st0)
    st = st0
    outs = []
    for t in range(10):
        y, st = rglru_decode_step(params, x[:, t:t + 1], cfg, CTX, st)
        outs.append(y)
    y_seq = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_scan.h), np.asarray(st.h),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# MoE
# ----------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2])
def test_moe_matches_reference_with_headroom(k):
    """With capacity_factor high enough to avoid drops, sort-based dispatch
    must equal the dense gather reference exactly."""
    rng = jax.random.PRNGKey(0)
    d, ff, e = 16, 32, 4
    params = init_moe(rng, d, ff, e, 0, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    got, aux = moe_mlp(params, x, experts_per_token=k, act_name="silu",
                       ctx=CTX, capacity_factor=float(e))
    want = moe_mlp_reference(params, x, experts_per_token=k, act_name="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux["load_balance"]))


def test_moe_shared_expert():
    rng = jax.random.PRNGKey(3)
    d, ff, e = 8, 16, 4
    params = init_moe(rng, d, ff, e, 1, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 6, d))
    got, _ = moe_mlp(params, x, experts_per_token=1, act_name="silu",
                     ctx=CTX, capacity_factor=float(e))
    want = moe_mlp_reference(params, x, experts_per_token=1, act_name="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_load_balance_uniform_router():
    """A zero router routes uniformly -> load balance loss ~= 1."""
    d, ff, e = 8, 16, 8
    params = init_moe(jax.random.PRNGKey(0), d, ff, e, 0, True, jnp.float32)
    params = dict(params, router=jnp.zeros((d, e)))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, d))
    _, aux = moe_mlp(params, x, experts_per_token=2, act_name="silu", ctx=CTX)
    assert 0.9 < float(aux["load_balance"]) < 1.1
