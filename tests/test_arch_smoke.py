"""Per-architecture smoke tests (reduced configs, CPU).

For each of the 10 assigned archs + the paper's ViT: instantiate the
reduced same-family config, run one forward/train step, assert output
shapes and finiteness. Prefill->decode consistency is asserted for one
arch per family (dense / moe / ssm / hybrid).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_reduced_config
from repro.configs.base import ServeConfig, TrainConfig
from repro.launch.steps import init_train_state, make_train_step
from repro.models import lm
from repro.models.common import ParallelCtx

CTX = ParallelCtx()
SV = ServeConfig(cache_dtype="float32")


def _batch(cfg, b=2, s=32):
    if cfg.input_mode == "embeddings":
        inputs = jnp.asarray(
            np.random.default_rng(0).normal(0, 1, (b, s, cfg.d_model)),
            jnp.float32)
    else:
        inputs = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (b, s)),
            jnp.int32)
    targets = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return inputs, targets


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_reduced_config(arch)
    tc = TrainConfig(microbatches=1, remat="none", z_loss=0.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc, None))
    inputs, targets = _batch(cfg)
    state2, metrics = step(state, {"inputs": inputs, "targets": targets},
                           jax.random.PRNGKey(2))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss"
    assert loss > 0
    # params actually changed
    p0 = jax.tree.leaves(state.params)[0] if False else None
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_microbatched_step_matches_shape(arch):
    cfg = get_reduced_config(arch)
    tc = TrainConfig(microbatches=2, remat="full", z_loss=0.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc, None))
    inputs, targets = _batch(cfg, b=4, s=16)
    state2, metrics = step(state, {"inputs": inputs, "targets": targets},
                           jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_smoke(arch):
    cfg = get_reduced_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    inputs, _ = _batch(cfg, b=2, s=32)
    logits, caches = lm.prefill(params, inputs, cfg, CTX, SV)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert caches is not None


@pytest.mark.parametrize(
    "arch", ["internlm2-1.8b", "qwen3-moe-235b-a22b", "mamba2-1.3b",
             "recurrentgemma-2b", "musicgen-medium"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Strong consistency: prefill(S) + decode steps == forward(S+T).

    Covers each serving family: dense GQA (internlm2), MoE (qwen3),
    SSD (mamba2), RG-LRU hybrid + local attn (recurrentgemma),
    sinusoidal-posemb audio (musicgen).

    MoE capacity is raised to the no-drop regime: token-drop patterns
    legitimately differ between a 24-token and a 28-token dispatch, so
    exact prefill==forward equality only holds dropless."""
    cfg = get_reduced_config(arch, moe_capacity_factor=64.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    S, T = 24, 4
    if cfg.input_mode == "embeddings":
        full_in = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (1, S + T, cfg.d_model)), jnp.float32)
    else:
        full_in = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (1, S + T)), jnp.int32)

    # oracle: full prefill over S+T gives the last-position logits
    want_logits, _ = lm.prefill(params, full_in, cfg, CTX, SV)

    # prefill S then decode T tokens
    logits, caches = lm.prefill(params, full_in[:, :S], cfg, CTX, SV)
    caches = lm.pad_caches(caches, cfg, S + T)
    for t in range(S, S + T):
        tok = full_in[:, t:t + 1]
        logits, caches = lm.decode_step(params, caches, tok, jnp.asarray(t),
                                        cfg, CTX, SV)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want_logits),
                               rtol=2e-2, atol=2e-2)


def test_vit_paper_config_features():
    from repro.configs import get_config
    from repro.features.vit import extract_features, init_vit
    from repro.configs.rapidearth_vit import FEATURE_DIM, IMAGE_SIZE, PATCH_SIZE
    cfg = get_config("rapidearth-vit-t")
    params = init_vit(jax.random.PRNGKey(0), cfg, image_size=IMAGE_SIZE,
                      patch_size=PATCH_SIZE)
    imgs = jnp.asarray(np.random.default_rng(0).uniform(
        0, 1, (3, IMAGE_SIZE, IMAGE_SIZE, 3)), jnp.float32)
    f = extract_features(params, imgs, cfg, CTX, patch_size=PATCH_SIZE)
    assert f.shape == (3, FEATURE_DIM)      # paper: 384 features per patch
    assert np.isfinite(np.asarray(f)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "granite-20b": dict(num_layers=52, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "nemotron-4-15b": dict(num_layers=32, d_model=6144, num_heads=48,
                               num_kv_heads=8, d_ff=24576, vocab_size=256000,
                               mlp_activation="relu2"),
        "internlm2-1.8b": dict(num_layers=24, d_model=2048, num_heads=16,
                               num_kv_heads=8, d_ff=8192, vocab_size=92544),
        "llama3-8b": dict(num_layers=32, d_model=4096, num_heads=32,
                          num_kv_heads=8, d_ff=14336, vocab_size=128256),
        "llava-next-mistral-7b": dict(num_layers=32, d_model=4096,
                                      num_heads=32, num_kv_heads=8,
                                      d_ff=14336, vocab_size=32000,
                                      input_mode="embeddings"),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
        "mamba2-1.3b": dict(num_layers=48, d_model=2048, ssm_state=128),
        "llama4-maverick-400b-a17b": dict(num_layers=48, d_model=5120,
                                          num_heads=40, num_kv_heads=8,
                                          d_ff=8192, vocab_size=202048,
                                          num_experts=128,
                                          experts_per_token=1),
        "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096,
                                    num_heads=64, num_kv_heads=4, d_ff=1536,
                                    vocab_size=151936, num_experts=128,
                                    experts_per_token=8),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                                  num_kv_heads=1, d_ff=7680,
                                  vocab_size=256000, local_window=2048),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_param_counts_sane():
    """Analytic param counts land in the advertised ballparks."""
    checks = {
        "granite-20b": (15e9, 26e9),
        "nemotron-4-15b": (12e9, 19e9),
        "internlm2-1.8b": (1.4e9, 2.4e9),
        "llama3-8b": (6e9, 9e9),
        "mamba2-1.3b": (1.0e9, 1.8e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "llama4-maverick-400b-a17b": (330e9, 470e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
    }
    for arch, (lo, hi) in checks.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n / 1e9:.1f}B outside [{lo / 1e9}, {hi / 1e9}]"


def test_active_params_moe():
    qwen = get_config("qwen3-moe-235b-a22b")
    a = qwen.active_param_count()
    assert 15e9 < a < 30e9, f"qwen3 active {a / 1e9:.1f}B"
    l4 = get_config("llama4-maverick-400b-a17b")
    a4 = l4.active_param_count()
    assert 10e9 < a4 < 25e9, f"llama4 active {a4 / 1e9:.1f}B"
