"""HTTP front end (ISSUE 9 / DESIGN.md §16): real-socket end-to-end.

Contracts pinned here:
  * ``POST /query`` over a real TCP socket returns the SAME answer as a
    direct ``engine.query`` call — ids and scores bitwise-equal through
    the JSON round trip;
  * the typed error taxonomy maps to the wire contract: rate_limited ->
    429, overloaded/shutdown -> 503 (+ Retry-After), deadline_exceeded
    -> 504, transport errors -> 400/404/405;
  * ``timeout_ms`` becomes an absolute monotonic deadline AT ADMISSION;
  * a repeated query serves from the cache (flagged, bitwise-equal) and
    ``POST /ingest`` invalidates — ``stale_hits`` stays 0 on the wire;
  * ``GET /healthz`` flips to 503 when draining; ``GET /stats`` carries
    the server summary plus cache and HTTP ledgers, JSON-clean;
  * HTTP/1.1 keep-alive serves several requests per connection.
"""
import contextlib
import json
import socket
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.engine import SearchEngine
from repro.serve.cache import ResultCache
from repro.serve.engine import QueryServer
from repro.serve.http import HttpFrontEnd, jsonable

ENG = dict(n_subsets=4, subset_dim=4, block=64)


def _data(n=500, d=16, seed=0):
    return np.random.default_rng(seed).normal(
        0, 1, (n, d)).astype(np.float32)


def _labels():
    return list(range(10)), list(range(100, 150))


@pytest.fixture(scope="module")
def base_x():
    return _data()


@contextlib.contextmanager
def _serving(srv, start_engine=True):
    """Front end over ``srv`` on an ephemeral port -> base URL."""
    if start_engine:
        srv.start()
    fe = HttpFrontEnd(srv)
    host, port = fe.start()
    try:
        yield f"http://{host}:{port}", fe
    finally:
        fe.close()
        srv.close(drain=False)


def _post(base, path, body, timeout=120):
    req = urllib.request.Request(
        base + path, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), dict(e.headers)


def _get(base, path, timeout=30):
    try:
        with urllib.request.urlopen(base + path, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


# ----------------------------------------------------------------------
# end-to-end correctness
# ----------------------------------------------------------------------

def test_query_bitwise_matches_direct_engine(base_x):
    eng = SearchEngine(base_x, **ENG, live=True)
    pos, neg = _labels()
    want = eng.query(pos, neg, model="dbranch", max_results=30)
    with _serving(QueryServer(eng, max_results=30)) as (base, _):
        status, body, _ = _post(base, "/query",
                                {"pos_ids": pos, "neg_ids": neg})
        assert status == 200 and body["ok"]
        # bitwise through the JSON round trip: float64 carries every
        # float32 exactly, so casting back reproduces the device answer
        np.testing.assert_array_equal(
            np.asarray(body["ids"], dtype=want.ids.dtype), want.ids)
        np.testing.assert_array_equal(
            np.asarray(body["scores"], dtype=want.scores.dtype),
            want.scores)
        assert body["n_found"] == want.n_found
        assert body["model"] == "dbranch"
        assert body["e2e_ms"] >= body["latency_ms"] >= 0


def test_cached_repeat_bitwise_and_ingest_invalidates(base_x):
    eng = SearchEngine(base_x, **ENG, live=True)
    srv = QueryServer(eng, max_results=30, cache=ResultCache())
    pos, neg = _labels()
    q = {"pos_ids": pos, "neg_ids": neg}
    with _serving(srv) as (base, _):
        s1, b1, _ = _post(base, "/query", q)
        s2, b2, _ = _post(base, "/query", q)
        assert (s1, s2) == (200, 200)
        assert b1["cache"] == "miss" and b2["cache"] == "hit"
        assert b2["ids"] == b1["ids"] and b2["scores"] == b1["scores"]
        si, bi, _ = _post(base, "/ingest",
                          {"op": "append",
                           "features": _data(4, seed=7).tolist()})
        assert si == 200 and bi["info"]["rows"] == 4
        assert bi["info"]["ids"] == [500, 501, 502, 503]
        s3, b3, _ = _post(base, "/query", q)
        assert s3 == 200 and b3["cache"] == "miss"   # epoch moved
        st, summary = _get(base, "/stats")
        assert st == 200
        assert summary["cache"]["stale_hits"] == 0   # never served stale
        assert summary["cache"]["hits"] == 1
        assert summary["cache_served"] == 1


def test_delete_and_compact_over_http(base_x):
    eng = SearchEngine(base_x, **ENG, live=True)
    srv = QueryServer(eng, max_results=10, cache=ResultCache())
    with _serving(srv) as (base, _):
        s, b, _ = _post(base, "/ingest", {"op": "delete", "ids": [5, 6]})
        assert s == 200 and b["info"]["rows"] == 2
        s, b, _ = _post(base, "/ingest", {"op": "compact"})
        assert s == 200 and b["info"]["background"]
        s, b, _ = _post(base, "/query",
                        {"pos_ids": [0, 1, 2], "neg_ids": [100, 101]})
        assert s == 200
        assert 5 not in b["ids"] and 6 not in b["ids"]


# ----------------------------------------------------------------------
# typed rejections -> HTTP statuses
# ----------------------------------------------------------------------

def test_deadline_maps_to_504(base_x):
    eng = SearchEngine(base_x, **ENG)
    with _serving(QueryServer(eng)) as (base, _):
        pos, neg = _labels()
        status, body, _ = _post(base, "/query",
                                {"pos_ids": pos, "neg_ids": neg,
                                 "timeout_ms": 0.001})
        assert status == 504
        assert body["error_type"] == "deadline_exceeded"
        assert not body["ok"]


def test_overloaded_maps_to_503_with_retry_after(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, queue_depth=1)
    # fill the admission queue OUT OF BAND (server not started, so the
    # queued request just sits there); the HTTP request is then shed
    parked = srv.submit(_req(0))
    with _serving(srv, start_engine=False) as (base, _):
        status, body, headers = _post(base, "/query",
                                      {"pos_ids": [0], "neg_ids": [100]})
        assert status == 503
        assert body["error_type"] == "overloaded"
        assert headers.get("Retry-After") == "1"
    assert parked.get(timeout=5).error_type == "shutdown"


def test_rate_limited_maps_to_429(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng, rate_limit=(0.001, 1))  # one-shot bucket
    with _serving(srv) as (base, _):
        q = {"pos_ids": [0, 1], "neg_ids": [100, 101]}
        s1, _, _ = _post(base, "/query", q)
        s2, body, headers = _post(base, "/query", q)
        assert s1 == 200 and s2 == 429
        assert body["error_type"] == "rate_limited"
        assert headers.get("Retry-After") == "1"
        # a different source has its own bucket
        s3, _, _ = _post(base, "/query", {**q, "source": "other"})
        assert s3 == 200


def test_shutdown_maps_to_503_and_healthz_drains(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)
    with _serving(srv) as (base, _):
        assert _get(base, "/healthz") == (200, {"ok": True,
                                                "health": "ok"})
        srv.close()
        status, body, _ = _post(base, "/query",
                                {"pos_ids": [0], "neg_ids": [100]})
        assert status == 503 and body["error_type"] == "shutdown"
        hs, hb = _get(base, "/healthz")
        assert hs == 503 and hb["health"] == "draining"


def _req(i):
    from repro.serve.engine import QueryRequest
    return QueryRequest(i, *_labels())


# ----------------------------------------------------------------------
# transport errors
# ----------------------------------------------------------------------

def test_transport_rejections(base_x):
    eng = SearchEngine(base_x, **ENG)
    with _serving(QueryServer(eng)) as (base, _):
        assert _get(base, "/nope")[0] == 404
        assert _get(base, "/query")[0] == 405      # GET on a POST route
        s, b, _ = _post(base, "/healthz", {})
        assert s == 405
        s, b, _ = _post(base, "/query", {"pos_ids": [0],
                                         "neg_ids": [1], "bogus": 2})
        assert s == 400 and "bogus" in b["error"]
        s, b, _ = _post(base, "/query", {"pos_ids": "zero",
                                         "neg_ids": [1]})
        assert s == 400 and b["error_type"] == "bad_request"
        s, b, _ = _post(base, "/query", {"pos_ids": [0], "neg_ids": [1],
                                         "timeout_ms": -5})
        assert s == 400
        s, b, _ = _post(base, "/ingest", {"op": "explode"})
        assert s == 400
        # malformed JSON via a raw socket (urllib insists on bytes anyway)
        host, port = base[7:].split(":")
        with socket.create_connection((host, int(port)), timeout=10) as c:
            c.sendall(b"POST /query HTTP/1.1\r\nContent-Length: 9\r\n"
                      b"Connection: close\r\n\r\nnot json!")
            reply = c.recv(65536).decode()
        assert reply.startswith("HTTP/1.1 400")


def test_keep_alive_serves_multiple_requests(base_x):
    eng = SearchEngine(base_x, **ENG)
    with _serving(QueryServer(eng)) as (base, fe):
        host, port = base[7:].split(":")
        body = json.dumps({"pos_ids": [0, 1], "neg_ids": [100]}).encode()
        head = (f"POST /query HTTP/1.1\r\nHost: x\r\n"
                f"Content-Length: {len(body)}\r\n\r\n").encode()
        with socket.create_connection((host, int(port)), timeout=120) as c:
            f = c.makefile("rb")
            for _ in range(3):             # same connection, 3 requests
                c.sendall(head + body)
                status_line = f.readline().decode()
                assert status_line.startswith("HTTP/1.1 200")
                clen = 0
                while True:
                    line = f.readline()
                    if line in (b"\r\n", b""):
                        break
                    k, _, v = line.decode().partition(":")
                    if k.strip().lower() == "content-length":
                        clen = int(v)
                payload = json.loads(f.read(clen))
                assert payload["ok"]
        stats = fe.http_stats()
        assert stats["by_route"]["/query"] == 3
        assert stats["http_2xx"] == 3


def test_stats_route_is_json_clean(base_x):
    eng = SearchEngine(base_x, **ENG, live=True)
    srv = QueryServer(eng, max_results=10, cache=ResultCache())
    with _serving(srv) as (base, _):
        _post(base, "/query", {"pos_ids": [0, 1], "neg_ids": [100, 101]})
        status, s = _get(base, "/stats")   # json.loads already proved it
        assert status == 200
        assert s["served"] == 1 and s["epoch"] == 0
        assert s["http"]["http_requests"] >= 1
        assert s["cache"]["entries"] == 1
        # the admitted ledger holds over the wire too
        assert s["admitted"] == s["served"] + s["ingests"] + \
            s["expired_in_queue"] + s["evicted"] + s["shutdown_unserved"]


def test_jsonable_sanitises_numpy():
    blob = {"a": np.arange(3, dtype=np.int32),
            "b": np.float32(1.5), "c": (np.int64(2), [np.bool_(True)]),
            "d": {"nested": np.float64(0.25)}, "e": None}
    out = json.loads(json.dumps(jsonable(blob)))
    assert out == {"a": [0, 1, 2], "b": 1.5, "c": [2, [True]],
                   "d": {"nested": 0.25}, "e": None}


def test_front_end_close_is_idempotent(base_x):
    eng = SearchEngine(base_x, **ENG)
    srv = QueryServer(eng)
    srv.start()
    fe = HttpFrontEnd(srv)
    fe.start()
    fe.close()
    fe.close()                             # double close is a no-op
    srv.close()
    with pytest.raises(RuntimeError):
        fe.start()                         # a front end is single-use
