"""HTTP serving demo: the wire-facing search application (DESIGN.md §16).

    PYTHONPATH=src python examples/http_search.py

Builds the engine once (offline phase), starts the threaded QueryServer
behind the asyncio HTTP front end on an ephemeral port, and drives it
the way the paper's web client would — plain JSON over HTTP:

  * a search for each object class, then the SAME searches again to
    show the epoch-keyed result cache answering without device time;
  * an append through ``POST /ingest``, proving the repeat query now
    misses (the catalog epoch moved — cached answers are never stale);
  * a deliberately tiny ``timeout_ms`` surfacing as HTTP 504;
  * the ``/stats`` ledger an operator would scrape.

Run ``python -m repro.serve.http --port 8080`` instead for a server
that stays up for manual curl experiments.
"""
import json
import time
import urllib.error
import urllib.request

import numpy as np

from repro.core.engine import SearchEngine
from repro.data.synthetic import (CLASS_IDS, PatchDatasetConfig,
                                  generate_patches, handcrafted_features)
from repro.serve.cache import ResultCache
from repro.serve.engine import QueryServer
from repro.serve.http import HttpFrontEnd


def _post(base, path, body):
    req = urllib.request.Request(
        base + path, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=600) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def main():
    data = generate_patches(PatchDatasetConfig(n_patches=30_000, seed=2))
    feats = handcrafted_features(data["images"])
    labels = data["labels"]
    engine = SearchEngine(feats, n_subsets=24, subset_dim=6, seed=2,
                          live=True)
    print(f"[offline] {engine.index_stats()}")

    server = QueryServer(engine, max_results=100, max_batch=4,
                         queue_depth=64, default_deadline_s=60.0,
                         cache=ResultCache())
    server.start()
    fe = HttpFrontEnd(server)
    host, port = fe.start()
    base = f"http://{host}:{port}"
    print(f"[http] listening on {base}")

    rng = np.random.default_rng(0)
    queries = {}
    for cls_name in ("forest", "water", "solar_panel"):
        cls = CLASS_IDS[cls_name]
        pos = rng.choice(np.nonzero(labels == cls)[0], 15, replace=False)
        neg = rng.choice(np.nonzero(labels != cls)[0], 100, replace=False)
        queries[cls_name] = {"pos_ids": [int(i) for i in pos],
                             "neg_ids": [int(i) for i in neg],
                             "timeout_ms": 60_000}

    for round_name in ("cold", "cached"):
        print(f"[{round_name}]")
        for cls_name, body in queries.items():
            status, resp = _post(base, "/query", body)
            cls = CLASS_IDS[cls_name]
            ids = np.asarray(resp["ids"], dtype=np.int64)
            prec = (labels[ids] == cls).mean() if len(ids) else 0.0
            print(f"  {cls_name:12s} HTTP {status}  "
                  f"{resp['n_found']:6d} found  "
                  f"{resp['e2e_ms']:8.1f} ms e2e  "
                  f"cache={resp['cache']:4s}  precision {prec:.2f}")

    # a live append moves the catalog epoch: every cached entry becomes
    # unreachable, so the repeat query recomputes on the new catalog
    status, resp = _post(base, "/ingest",
                         {"op": "append",
                          "features": feats[:8].tolist()})
    print(f"[ingest] HTTP {status}  {resp['info']}")
    status, resp = _post(base, "/query", queries["forest"])
    print(f"[post-ingest] forest HTTP {status}  cache={resp['cache']} "
          "(epoch moved; never served stale)")

    # a budget too small to finish comes back typed on the wire
    status, resp = _post(base, "/query",
                         {**queries["water"], "timeout_ms": 0.001})
    print(f"[deadline] HTTP {status}  error_type={resp['error_type']}")

    status, stats = _post(base, "/query", queries["water"])  # warm again
    with urllib.request.urlopen(base + "/stats", timeout=60) as r:
        summary = json.loads(r.read())
    print(f"[stats] served={summary['served']} "
          f"cache_hits={summary['cache']['hits']} "
          f"hit_rate={summary['cache']['hit_rate']:.2f} "
          f"stale_hits={summary['cache']['stale_hits']} "
          f"http_2xx={summary['http']['http_2xx']}")
    t0 = time.perf_counter()
    fe.close()
    server.close()
    print(f"[shutdown] drained in {time.perf_counter() - t0:.2f}s")


if __name__ == "__main__":
    main()
