"""Train a feature extractor end to end, then plug it into the engine.

    PYTHONPATH=src python examples/train_extractor.py [--steps 300]

Two training modes, matching the paper's offline phase:
  * ``--mode dino``  (default): self-supervised DINO on synthetic patches
    with the paper's ViT-T (reduced size for CPU), then bulk-extract
    features and run a search query against them.
  * ``--mode lm``: train a ~100M-parameter causal LM (the internlm2
    family config scaled to ~100M) for a few hundred steps on the
    synthetic token stream — the "assigned architectures as extractor
    backbones" path, with checkpoint/restart.
"""
import argparse
import logging
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig
from repro.data.synthetic import (CLASS_IDS, PatchDatasetConfig,
                                  generate_patches)
from repro.models.common import ParallelCtx


def run_dino(steps: int) -> None:
    import jax.numpy as jnp
    from repro.core.engine import SearchEngine
    from repro.features.dino import init_dino, make_dino_step
    from repro.features.extract import extract_catalog, vit_feature_fn

    cfg = ModelConfig(name="vit-t-mini", family="vit", num_layers=4,
                      d_model=96, num_heads=3, num_kv_heads=3, head_dim=32,
                      d_ff=384, vocab_size=0, mlp_gated=False,
                      mlp_activation="gelu")
    image_size, patch_size = 32, 8
    ctx = ParallelCtx()
    data = generate_patches(PatchDatasetConfig(n_patches=2048,
                                               patch_size=image_size, seed=1))
    imgs = data["images"]

    state = init_dino(jax.random.PRNGKey(0), cfg, image_size=image_size,
                      patch_size=patch_size)
    step = jax.jit(make_dino_step(cfg, image_size=image_size,
                                  patch_size=patch_size, ctx=ctx))
    print(f"[dino] training ViT ({sum(x.size for x in jax.tree.leaves(state.student)):,} params) "
          f"for {steps} steps ...")
    B = 64
    t0 = time.perf_counter()
    for i in range(steps):
        batch = imgs[(i * B) % len(imgs):(i * B) % len(imgs) + B]
        if len(batch) < B:
            batch = imgs[:B]
        state, m = step(state, jax.numpy.asarray(batch), jax.random.PRNGKey(i))
        if i % max(steps // 10, 1) == 0:
            print(f"  step {i:4d}  dino loss {float(m['loss']):.4f}")
    print(f"[dino] {steps} steps in {time.perf_counter() - t0:.1f}s")

    print("[extract] embedding the catalog with the trained student ...")
    fn = vit_feature_fn(cfg, ctx, patch_size=patch_size)
    feats = extract_catalog(state.student, imgs, fn, batch=128)
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)

    engine = SearchEngine(feats, n_subsets=16, subset_dim=6, seed=0)
    cls = CLASS_IDS["water"]
    rng = np.random.default_rng(0)
    pos = rng.choice(np.nonzero(data["labels"] == cls)[0], 15, replace=False)
    neg = rng.choice(np.nonzero(data["labels"] != cls)[0], 80, replace=False)
    res = engine.query(pos, neg, model="dbens", n_models=10)
    prec = (data["labels"][res.ids] == cls).mean() if res.n_found else 0.0
    print(f"[search] {res.summary()}  precision={prec:.2f} "
          f"(base rate {(data['labels'] == cls).mean():.2f})")


def run_lm(steps: int, checkpoint_dir: str) -> None:
    from repro.train.trainer import Trainer

    # ~100M params: 12L x 768d x 3072ff, vocab 8192
    cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                      d_model=768, num_heads=12, num_kv_heads=4, d_ff=3072,
                      vocab_size=8192, param_dtype="float32",
                      compute_dtype="float32")
    print(f"[lm] {cfg.name}: {cfg.param_count() / 1e6:.0f}M params, "
          f"{steps} steps")
    tc = TrainConfig(learning_rate=3e-4, warmup_steps=20, total_steps=steps,
                     z_loss=0.0, remat="none")
    dc = DataConfig(seq_len=256, global_batch=8, vocab_size=cfg.vocab_size)
    tr = Trainer(cfg, tc, dc, checkpoint_dir=checkpoint_dir,
                 checkpoint_every=100, step_deadline_s=900)
    state, report = tr.run(steps, log_every=max(steps // 10, 1))
    print(f"[lm] loss {report.losses[0]:.3f} -> {report.final_loss:.3f}  "
          f"({report.tokens_per_s:,.0f} tokens/s, "
          f"resumed_from={report.resumed_from})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="dino", choices=["dino", "lm"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    if args.mode == "dino":
        run_dino(args.steps)
    else:
        run_lm(args.steps, args.checkpoint_dir)


if __name__ == "__main__":
    main()
