"""Live catalog ingestion: a catalog that grows with every satellite
pass (DESIGN.md §12).

    PYTHONPATH=src python examples/live_catalog.py

1. Build a LIVE engine over yesterday's catalog (one base segment).
2. Query it, then ingest today's pass with ``append`` — only the new
   rows are Morton-ordered; no rebuild, and every old row keeps its id.
3. Re-run the query: newly ingested matches appear immediately.
4. Retire bad patches with ``delete`` — tombstones in a device-resident
   validity mask; ranked results never surface them again.
5. ``compact`` in the background: segments merge into one fresh Morton
   order off the serving thread and swap in atomically under a new
   epoch, while queries keep running on the snapshot they started with.
"""
import time

import numpy as np

from repro.core.engine import SearchEngine
from repro.data.synthetic import (CLASS_IDS, PatchDatasetConfig,
                                  generate_patches, handcrafted_features)


def make_pass(n, seed):
    data = generate_patches(PatchDatasetConfig(n_patches=n, seed=seed))
    return handcrafted_features(data["images"]), data["labels"]


def main():
    print("=== RapidEarth live catalog ===")
    feats, labels = make_pass(12_000, seed=7)
    engine = SearchEngine(feats, n_subsets=24, subset_dim=6, seed=7,
                          live=True, max_results=200)
    st = engine.index_stats()
    print(f"[1] live engine over {st['rows']} rows, "
          f"{st['n_segments']} segment, epoch {st['epoch']}")

    cls = CLASS_IDS["forest"]
    rng = np.random.default_rng(0)
    pos = rng.choice(np.nonzero(labels == cls)[0], 20, replace=False)
    neg = rng.choice(np.nonzero(labels != cls)[0], 120, replace=False)
    res = engine.query(pos, neg, model="dbranch")
    print(f"[2] {res.summary()}")

    # today's pass arrives: append seals it into a delta segment
    new_feats, new_labels = make_pass(3_000, seed=11)
    t0 = time.perf_counter()
    new_ids = engine.append(new_feats)
    st = engine.index_stats()
    print(f"[3] appended {len(new_ids)} rows in "
          f"{time.perf_counter() - t0:.3f}s -> {st['n_segments']} "
          f"segments, epoch {st['epoch']} (ids "
          f"{new_ids[0]}..{new_ids[-1]}, stable forever)")

    res2 = engine.query(pos, neg, model="dbranch", max_results=None)
    fresh = np.intersect1d(res2.ids, new_ids)
    print(f"[4] re-query (full results): {res2.n_found} matches, "
          f"{len(fresh)} from today's pass")

    # an analyst flags some results as bad imagery: tombstone them
    dead = [int(i) for i in res2.ids[:5]]
    engine.delete(dead)
    res3 = engine.query(pos, neg, model="dbranch")
    assert not np.intersect1d(res3.ids, dead).size
    st = engine.index_stats()
    print(f"[5] deleted {len(dead)} rows (tombstoned: "
          f"{st['rows_tombstoned']}); they no longer rank")

    # background compaction: merge segments off the serving thread
    t = engine.compact(background=True)
    res4 = engine.query(pos, neg, model="dbranch")   # serves meanwhile
    t.join()
    st = engine.index_stats()
    print(f"[6] compacted -> {st['n_segments']} segment, epoch "
          f"{st['epoch']}; results unchanged: "
          f"{np.array_equal(res3.ids, engine.query(pos, neg).ids)}")
    assert np.array_equal(res3.ids, res4.ids)


if __name__ == "__main__":
    main()
