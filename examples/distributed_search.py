"""Sharded search: the production fan-out on a (simulated) device mesh.

    PYTHONPATH=src python examples/distributed_search.py

Spawns 8 placeholder CPU devices (this script owns its process, like
dryrun.py), shards the zone-map index over the `data` mesh axis, runs the
shard_map'd prune+refine, and checks the result against the single-host
engine — the exact query fan-out a pod deployment uses (DESIGN.md §8).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.boxes import BoxSet  # noqa: E402
from repro.core.dbranch import fit_dbranch_best_subset  # noqa: E402
from repro.core.index import build_index, distributed_query, query_index  # noqa: E402
from repro.core.subsets import make_subsets  # noqa: E402
from repro.data.synthetic import (CLASS_IDS, PatchDatasetConfig,  # noqa: E402
                                  generate_patches, handcrafted_features)


def main():
    assert len(jax.devices()) == 8, jax.devices()
    data = generate_patches(PatchDatasetConfig(n_patches=32_768, seed=4))
    feats = handcrafted_features(data["images"])
    labels = data["labels"]

    subsets = make_subsets(feats.shape[1], 16, 6, seed=4)
    cls = CLASS_IDS["forest"]
    rng = np.random.default_rng(1)
    pos = rng.choice(np.nonzero(labels == cls)[0], 20, replace=False)
    neg = rng.choice(np.nonzero(labels != cls)[0], 120, replace=False)

    boxes = fit_dbranch_best_subset(feats[pos], feats[neg], subsets)
    print(f"[fit] DBranch: {boxes.n_boxes} boxes on subset {boxes.subset_id} "
          f"(dims {boxes.dims.tolist()})")

    index = build_index(feats, boxes.dims, block=512,
                        subset_id=boxes.subset_id)
    mesh = jax.make_mesh((8,), ("data",))
    rows = index.rows.reshape(index.n_blocks, index.block, -1)

    t0 = time.perf_counter()
    counts_sharded = np.asarray(distributed_query(
        jnp.asarray(rows), jnp.asarray(index.zlo), jnp.asarray(index.zhi),
        jnp.asarray(boxes.lo), jnp.asarray(boxes.hi), mesh, index.block))
    dt = time.perf_counter() - t0

    # back to original row order, compare with the local path
    back = np.zeros(index.n_rows, np.int64)
    valid = index.perm >= 0
    back[index.perm[valid]] = counts_sharded[valid]
    counts_local, stats = query_index(index, boxes)
    assert (back == counts_local).all(), "sharded result != local result"

    found = np.nonzero(back > 0)[0]
    found = found[~np.isin(found, np.concatenate([pos, neg]))]
    prec = (labels[found] == cls).mean() if len(found) else 0.0
    print(f"[query] sharded over {mesh.devices.size} devices in "
          f"{1e3 * dt:.1f} ms -> {len(found)} results, precision {prec:.2f}")
    print(f"[query] local path stats: {stats}")
    print("[ok] sharded == local: the query fan-out is exact")


if __name__ == "__main__":
    main()
