"""Quickstart: the full RapidEarth workflow in one script.

    PYTHONPATH=src python examples/quickstart.py

1. Generate a synthetic aerial catalog (procedural Denmark stand-in).
2. Extract 384-d features per patch.
3. Build the feature subsets + zone-map indexes (offline phase).
4. Label a few solar-panel patches positive, a few random patches
   negative (what the web UI's clicks produce).
5. Fit decision branches, run the range queries, rank the results —
   and compare against the scan-based decision tree.
"""
import time

import numpy as np

from repro.core.engine import SearchEngine
from repro.data.synthetic import (CLASS_IDS, CLASSES, PatchDatasetConfig,
                                  generate_patches, handcrafted_features)


def main():
    print("=== RapidEarth quickstart ===")
    t0 = time.perf_counter()
    cfg = PatchDatasetConfig(n_patches=20_000, seed=7)
    data = generate_patches(cfg)
    print(f"[1] generated {cfg.n_patches} patches "
          f"({time.perf_counter() - t0:.1f}s); class counts:",
          {CLASSES[i]: int((data['labels'] == i).sum())
           for i in range(len(CLASSES))})

    t0 = time.perf_counter()
    feats = handcrafted_features(data["images"])
    print(f"[2] extracted features {feats.shape} "
          f"({time.perf_counter() - t0:.1f}s)")

    engine = SearchEngine(feats, n_subsets=24, subset_dim=6, seed=7)
    st = engine.index_stats()
    print(f"[3] built {st['n_subsets']} zone-map indexes in "
          f"{st['build_time_s']:.2f}s "
          f"({st['index_bytes'] / 1e6:.1f} MB index / "
          f"{st['feature_bytes'] / 1e6:.1f} MB features)")

    # the user labels a handful of patches on the map
    cls = CLASS_IDS["forest"]
    rng = np.random.default_rng(0)
    pos = rng.choice(np.nonzero(data["labels"] == cls)[0], 20, replace=False)
    neg = rng.choice(np.nonzero(data["labels"] != cls)[0], 120, replace=False)
    print(f"[4] user labels: {len(pos)} positive, {len(neg)} negative")

    for model in ("dbranch", "dbens", "dtree", "rforest", "knn"):
        kw = dict(n_models=15) if model in ("dbens", "rforest") else {}
        res = engine.query(pos, neg, model=model, **kw)
        prec = (data["labels"][res.ids] == cls).mean() if res.n_found else 0.0
        path = res.stats.get("path", "?")
        bytes_frac = res.stats.get("bytes_touched", 0) / feats.nbytes
        print(f"[5] {res.summary():68s} path={path:5s} "
              f"bytes={bytes_frac:6.1%} precision={prec:.2f}")

    print("\nRefinement (paper §5): add the false positives as negatives,"
          " re-query:")
    res = engine.query(pos, neg, model="dbens", n_models=15)
    wrong = res.ids[data["labels"][res.ids] != cls][:40]
    res2 = engine.refine(res, [], wrong, pos, neg, n_models=15)
    p1 = (data["labels"][res.ids] == cls).mean() if res.n_found else 0
    p2 = (data["labels"][res2.ids] == cls).mean() if res2.n_found else 0
    print(f"    precision {p1:.2f} -> {p2:.2f} "
          f"({res.n_found} -> {res2.n_found} results, "
          f"{1e3 * (res2.train_time_s + res2.query_time_s):.0f} ms)")


if __name__ == "__main__":
    main()
