"""Batched query serving demo (the paper's deployed "search application").

    PYTHONPATH=src python examples/serve_search.py

Builds the engine once (offline phase), starts the threaded QueryServer,
submits a concurrent stream of user queries for different object classes
(including the refinement round-trip), and prints latency statistics —
the offline analogue of https://web.rapid.earth.
"""
import time

import numpy as np

from repro.core.engine import SearchEngine
from repro.data.synthetic import (CLASS_IDS, CLASSES, PatchDatasetConfig,
                                  generate_patches, handcrafted_features)
from repro.serve.engine import QueryRequest, QueryServer


def main():
    data = generate_patches(PatchDatasetConfig(n_patches=30_000, seed=2))
    feats = handcrafted_features(data["images"])
    labels = data["labels"]
    engine = SearchEngine(feats, n_subsets=24, subset_dim=6, seed=2)
    print(f"[offline] {engine.index_stats()}")

    server = QueryServer(engine, max_batch=4)
    server.start()
    rng = np.random.default_rng(0)

    # a mixed stream: different users, classes and models
    work = []
    for i, (cls_name, model) in enumerate([
            ("forest", "dbranch"), ("water", "dbranch"),
            ("forest", "dbens"), ("solar_panel", "dbens"),
            ("water", "knn"), ("forest", "dtree"),
            ("water", "dbens"), ("solar_panel", "dbranch")]):
        cls = CLASS_IDS[cls_name]
        pos = rng.choice(np.nonzero(labels == cls)[0], 15, replace=False)
        neg = rng.choice(np.nonzero(labels != cls)[0], 100, replace=False)
        kw = dict(n_models=10) if model in ("dbens", "rforest") else {}
        work.append((cls_name, model,
                     server.submit(QueryRequest(i, pos, neg, model, kw))))

    t0 = time.perf_counter()
    for cls_name, model, pending in work:
        resp = pending.get(timeout=600)
        if not resp.ok:
            print(f"  {cls_name:12s} {model:8s} ERROR {resp.error}")
            continue
        r = resp.result
        cls = CLASS_IDS[cls_name]
        prec = (labels[r.ids] == cls).mean() if r.n_found else 0.0
        print(f"  {cls_name:12s} {model:8s} {r.n_found:6d} found  "
              f"{1e3 * resp.latency_s:7.1f} ms  precision {prec:.2f}")
    print(f"[serve] stream completed in {time.perf_counter() - t0:.2f}s; "
          f"stats: {server.summary()}")
    server.close()


if __name__ == "__main__":
    main()
